//! Per-(sequence, layer) KV cache: packed quantized region + fp32 residual
//! ring, in exactly the memory layout the AOT layer artifacts consume, so
//! batch assembly is a straight memcpy per tensor.
//!
//! Layouts (row-major; `Tc` = allocated quantized capacity in tokens,
//! `Rc` = allocated residual capacity in tokens — see *Paged allocation*):
//!   packed K   [H, Tc·kb/8, Dh] u8     scales/zeros [H, Tc/G, Dh] f32
//!   packed V   [H, Tc, Dh·vb/8] u8     scales/zeros [H, Tc, Dh/G2] f32
//!   residual   [Rc, H, Dh] f32 ring (token-major so an append is one
//!              contiguous row write); materialized to [H, R, Dh] on gather
//!
//! Paged allocation: storage is **demand-paged** in group-aligned pages of
//! `G` tokens instead of being pre-allocated for the full context. A fresh
//! cache holds no token storage at all; `append_token`/`append_tokens`/
//! `fold_oldest_group` grow the packed region and the residual ring to the
//! exact page-rounded need (`q_capacity()` ≤ T, `res_capacity()` ≤ R).
//! Growth is deterministic — the same token stream always produces the
//! same capacities, whatever the append granularity — so
//! [`LayerCache::growth_bytes_for`] predicts the byte delta of an append
//! *exactly*, which is what [`super::pool::CachePool`] charges and gates
//! on. Every per-head stride of the packed buffers derives from the
//! current capacity, not from T; growth restrides with one memcpy per head
//! per tensor.
//!
//! Fold policy (ABI shared with python/compile/engine_sim.py): before
//! appending C tokens, fold the OLDEST group of G residual tokens into the
//! packed region while n_res + C > R. Folding runs the same RTN math as the
//! fold artifacts (bit-exact; asserted against golden.json).
//!
//! Change tracking: every cache carries a **monotonically bumped version**
//! plus a dirty descriptor split by region — `layout_version` (strides
//! changed: restride on page growth, wholesale replacement),
//! `packed_version` (a fold appended groups to the packed region) and
//! `res_base_version` (the residual ring's origin moved: fold consumed the
//! oldest group, the ring grew/compacted, or the cache was replaced).
//! Version values are drawn from one process-global counter, so **equal
//! versions imply byte-identical state**: a value is assigned exactly once,
//! and the only way two caches share it is a clone lineage — and `Clone`
//! deliberately re-stamps every version (including the `ident_version`
//! object-identity stamp), so a restored snapshot (prefix cache, session
//! replay) can never alias a live cache's history. While `ident_version`
//! is stable a cache's history is linear and append-only, which is what
//! lets the engine's literal cache patch *only the appended tail*: same
//! ident + newer `packed_version` ⟹ folds appended groups
//! `[seen_n_q/G, n_q/G)` and touched nothing below; same
//! `res_base_version` ⟹ residual rows `[0, seen_len)` are untouched.
//! Code that mutates the (public) buffers directly without going through
//! the append/fold API must call [`LayerCache::invalidate`].
//!
//! Shared prefixes: a cache may be **attached** to an immutable, refcounted
//! [`LayerBase`] ([`LayerCache::attach`]) holding a frozen prefix — its
//! packed groups AND its residual rows at snapshot time. Attached caches
//! read the base through `Arc` (zero copy, charged once pool-wide) and
//! write only a private tail: appends land in the private ring, folds pack
//! into private buffers past `n_base`, and fold reads *consume* base
//! residual rows without ever writing them — copy-on-write where the only
//! bytes ever copied are the divergent ones. `capacity_bytes` counts the
//! private tail only; the pool charges the base once per unique prefix.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::quant::kernels as rtn;
use crate::quant::kernels::GroupParams;
use crate::quant::Bits;

/// Process-global version source: each bump is globally unique, so version
/// equality across ANY two caches proves byte-identical region state.
fn next_version() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Process-unique id for externally rebuilt [`LayerBase`]s (the hibernation
/// decode path) — same stamp source as live caches, so ids never collide
/// with frozen-from-live bases.
pub(crate) fn fresh_base_id() -> u64 {
    next_version()
}

/// Geometry shared by every layer cache of a model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheGeometry {
    pub n_heads: usize,
    pub max_ctx: usize,   // T
    pub d_head: usize,    // Dh
    pub group: usize,     // G
    pub residual: usize,  // R
}

impl CacheGeometry {
    pub fn g2(&self) -> usize {
        self.group.min(self.d_head)
    }
}

/// Fold one [G, Dh] K group into a single head's buffers, passed as
/// head-relative views (strides inside a head derive only from `g`/`dh`;
/// the caller slices per head). Exactly one representation is active per
/// bit mode: `k_f32` for fp32 (`bits == 0`), `k_pk` + params otherwise —
/// the inactive views may be empty. A free function (not a method) so the
/// multi-head prefill fold can run heads on scoped worker threads holding
/// disjoint `&mut` head views.
#[allow(clippy::too_many_arguments)]
fn fold_k_into(
    kg: &[f32],
    gi: usize,
    g: usize,
    dh: usize,
    bits: Bits,
    k_pk: &mut [u8],
    k_f32: &mut [f32],
    k_scales: &mut [f32],
    k_zeros: &mut [f32],
) {
    if bits == 0 {
        let base = gi * g * dh;
        k_f32[base..base + g * dh].copy_from_slice(kg);
        return;
    }
    let rows_pk = rtn::packed_len(g, bits); // bytes along token axis
    let mut params = vec![GroupParams { scale: 0.0, zero: 0.0 }; dh];
    let dst = gi * rows_pk * dh;
    rtn::fold_k_group(kg, g, dh, bits, &mut k_pk[dst..dst + rows_pk * dh], &mut params);
    let pbase = gi * dh;
    for d in 0..dh {
        k_scales[pbase + d] = params[d].scale;
        k_zeros[pbase + d] = params[d].zero;
    }
}

/// V-side counterpart of [`fold_k_into`]: fold one [G, Dh] group per token
/// into a single head's views.
#[allow(clippy::too_many_arguments)]
fn fold_v_into(
    vg: &[f32],
    gi: usize,
    g: usize,
    dh: usize,
    g2: usize,
    bits: Bits,
    v_pk: &mut [u8],
    v_f32: &mut [f32],
    v_scales: &mut [f32],
    v_zeros: &mut [f32],
) {
    let oq = gi * g; // own-relative token offset of this group
    if bits == 0 {
        let base = oq * dh;
        v_f32[base..base + g * dh].copy_from_slice(vg);
        return;
    }
    let bpt = rtn::packed_len(dh, bits); // bytes per token
    let dg = dh / g2;
    let mut params = vec![GroupParams { scale: 0.0, zero: 0.0 }; g * dg];
    let dst = oq * bpt;
    rtn::fold_v_group(vg, g, dh, g2, bits, &mut v_pk[dst..dst + g * bpt], &mut params);
    let pbase = oq * dg;
    for i in 0..g * dg {
        v_scales[pbase + i] = params[i].scale;
        v_zeros[pbase + i] = params[i].zero;
    }
}

/// One head's destination views for the parallel batch fold.
struct HeadFoldDst<'a> {
    head: usize,
    k_pk: &'a mut [u8],
    k_f32: &'a mut [f32],
    k_scales: &'a mut [f32],
    k_zeros: &'a mut [f32],
    v_pk: &'a mut [u8],
    v_f32: &'a mut [f32],
    v_scales: &'a mut [f32],
    v_zeros: &'a mut [f32],
}

/// Split `buf` into `h` per-head views of `per` elements (empty views when
/// the representation is inactive for the current bit mode).
fn head_views<T>(buf: &mut [T], per: usize, h: usize, active: bool) -> Vec<&mut [T]> {
    if !active || per == 0 {
        (0..h).map(|_| Default::default()).collect()
    } else {
        buf.chunks_mut(per).take(h).collect()
    }
}

/// Round a token count up to whole `g`-token pages, capped at `limit`.
fn page_target(need: usize, g: usize, limit: usize) -> usize {
    (need.div_ceil(g) * g).min(limit)
}

/// An immutable frozen prefix: the packed quantized region at **exact**
/// strides (capacity == `n_base`) plus the residual rows at snapshot time,
/// compacted token-major. Shared read-only by every attached [`LayerCache`]
/// through an `Arc` — never mutated after construction, so equal `id` means
/// identical bytes forever (the process-wide literal cache keys on it).
#[derive(Debug)]
pub struct LayerBase {
    /// process-unique identity (same version source as cache stamps)
    pub id: u64,
    pub geo: CacheGeometry,
    pub k_bits: Bits,
    pub v_bits: Bits,
    /// frozen quantized token count (multiple of G; drives every stride)
    pub n_base: usize,
    // --- packed region, capacity == n_base ---
    pub k_pk: Vec<u8>,
    pub k_f32: Vec<f32>,
    pub k_scales: Vec<f32>,
    pub k_zeros: Vec<f32>,
    pub v_pk: Vec<u8>,
    pub v_f32: Vec<f32>,
    pub v_scales: Vec<f32>,
    pub v_zeros: Vec<f32>,
    /// residual rows at snapshot time, `[res_rows, H, Dh]` token-major
    pub res_rows: usize,
    pub res_k: Vec<f32>,
    pub res_v: Vec<f32>,
}

impl LayerBase {
    /// Total frozen tokens (quantized + residual snapshot rows).
    pub fn n_tokens(&self) -> usize {
        self.n_base + self.res_rows
    }

    /// Allocation footprint of the shared buffers — what the pool charges
    /// ONCE per unique base, however many sequences attach.
    pub fn bytes(&self) -> usize {
        self.k_pk.len()
            + self.v_pk.len()
            + 4 * (self.k_f32.len()
                + self.v_f32.len()
                + self.k_scales.len()
                + self.k_zeros.len()
                + self.v_scales.len()
                + self.v_zeros.len()
                + self.res_k.len()
                + self.res_v.len())
    }
}

#[derive(Debug)]
pub struct LayerCache {
    pub geo: CacheGeometry,
    pub k_bits: Bits,
    pub v_bits: Bits,
    // --- change tracking (see module docs; all values from next_version) ---
    /// object identity: stamped at construction, clone and invalidate ONLY
    /// — while unchanged, the cache's history is linear (append-only folds,
    /// tail-only ring appends), which is what makes tail patches sound
    ident_version: u64,
    /// bumped on every mutation
    version: u64,
    /// bumped when packed-region strides change (restride / replacement)
    layout_version: u64,
    /// bumped when a fold appends groups to the packed region
    packed_version: u64,
    /// bumped when the residual ring's origin moves (fold / growth /
    /// replacement) — appends leave it alone, enabling tail patches
    res_base_version: u64,
    /// quantized token count (multiple of G)
    pub n_q: usize,
    /// allocated quantized-region capacity in tokens (page-aligned, ≤ T);
    /// every packed/scale/zero stride derives from this
    q_cap: usize,
    // --- K side (packed when k_bits > 0, fp32 otherwise) ---
    pub k_pk: Vec<u8>,
    pub k_f32: Vec<f32>,
    pub k_scales: Vec<f32>,
    pub k_zeros: Vec<f32>,
    // --- V side ---
    pub v_pk: Vec<u8>,
    pub v_f32: Vec<f32>,
    pub v_scales: Vec<f32>,
    pub v_zeros: Vec<f32>,
    // --- fp32 residual ring, [Rc, H, Dh] token-major ---
    res_k: Vec<f32>,
    res_v: Vec<f32>,
    /// allocated ring capacity in tokens (page-aligned, ≤ R)
    res_cap: usize,
    res_start: usize,
    res_len: usize,
    // --- shared frozen prefix (None for root caches) ---
    base: Option<Arc<LayerBase>>,
    /// base residual rows already consumed by folds: logical rows
    /// `[0, base_res_off)` of the snapshot were folded into OUR private
    /// packed region; the base itself is never written
    base_res_off: usize,
}

/// Cloning re-stamps every version: a clone is a *different* cache whose
/// future diverges from the source's, so it must never be patch-compatible
/// with literals built from the source (or vice versa). This is what makes
/// prefix-restore / snapshot-replay a guaranteed full invalidation.
impl Clone for LayerCache {
    fn clone(&self) -> Self {
        Self {
            geo: self.geo,
            k_bits: self.k_bits,
            v_bits: self.v_bits,
            ident_version: next_version(),
            version: next_version(),
            layout_version: next_version(),
            packed_version: next_version(),
            res_base_version: next_version(),
            n_q: self.n_q,
            q_cap: self.q_cap,
            k_pk: self.k_pk.clone(),
            k_f32: self.k_f32.clone(),
            k_scales: self.k_scales.clone(),
            k_zeros: self.k_zeros.clone(),
            v_pk: self.v_pk.clone(),
            v_f32: self.v_f32.clone(),
            v_scales: self.v_scales.clone(),
            v_zeros: self.v_zeros.clone(),
            res_k: self.res_k.clone(),
            res_v: self.res_v.clone(),
            res_cap: self.res_cap,
            res_start: self.res_start,
            res_len: self.res_len,
            base: self.base.clone(),
            base_res_off: self.base_res_off,
        }
    }
}

impl LayerCache {
    /// A fresh cache allocates NO token storage (demand paging); only the
    /// fp32 paths carry their fixed dummy scale/zero rows (artifact ABI).
    pub fn new(geo: CacheGeometry, k_bits: Bits, v_bits: Bits) -> Self {
        let h = geo.n_heads;
        let (k_scales, k_zeros) = if k_bits > 0 {
            (vec![], vec![])
        } else {
            (vec![0f32; h], vec![0f32; h])
        };
        let (v_scales, v_zeros) = if v_bits > 0 {
            (vec![], vec![])
        } else {
            (vec![0f32; h], vec![0f32; h])
        };
        Self {
            geo,
            k_bits,
            v_bits,
            ident_version: next_version(),
            version: next_version(),
            layout_version: next_version(),
            packed_version: next_version(),
            res_base_version: next_version(),
            n_q: 0,
            q_cap: 0,
            k_pk: vec![],
            k_f32: vec![],
            k_scales,
            k_zeros,
            v_pk: vec![],
            v_f32: vec![],
            v_scales,
            v_zeros,
            res_k: vec![],
            res_v: vec![],
            res_cap: 0,
            res_start: 0,
            res_len: 0,
            base: None,
            base_res_off: 0,
        }
    }

    /// Attach to a frozen shared prefix: the new cache starts AT the
    /// snapshot (same `n_q`, same residual rows, so every future fold
    /// lands exactly where a from-scratch prefill would put it — folding
    /// is lossy, so matching the fold schedule is what makes attached
    /// decode bit-identical) while allocating **zero** token storage of
    /// its own. All private strides are relative to the base: packed
    /// buffers hold only groups past `n_base`, the ring holds only tokens
    /// appended after the snapshot.
    pub fn attach(base: Arc<LayerBase>) -> Self {
        let geo = base.geo;
        assert_eq!(base.n_base % geo.group, 0, "attach: base not group-aligned");
        assert!(base.n_base <= geo.max_ctx && base.res_rows <= geo.residual,
                "attach: base exceeds geometry");
        let h = geo.n_heads;
        let (k_scales, k_zeros) = if base.k_bits > 0 {
            (vec![], vec![])
        } else {
            (vec![0f32; h], vec![0f32; h])
        };
        let (v_scales, v_zeros) = if base.v_bits > 0 {
            (vec![], vec![])
        } else {
            (vec![0f32; h], vec![0f32; h])
        };
        Self {
            geo,
            k_bits: base.k_bits,
            v_bits: base.v_bits,
            ident_version: next_version(),
            version: next_version(),
            layout_version: next_version(),
            packed_version: next_version(),
            res_base_version: next_version(),
            n_q: base.n_base,
            q_cap: 0,
            k_pk: vec![],
            k_f32: vec![],
            k_scales,
            k_zeros,
            v_pk: vec![],
            v_f32: vec![],
            v_scales,
            v_zeros,
            res_k: vec![],
            res_v: vec![],
            res_cap: 0,
            res_start: 0,
            res_len: 0,
            base: Some(base),
            base_res_off: 0,
        }
    }

    /// The frozen shared prefix this cache reads through, if any.
    pub fn base(&self) -> Option<&Arc<LayerBase>> {
        self.base.as_ref()
    }

    /// Quantized tokens supplied by the shared base (0 for root caches).
    pub fn n_base(&self) -> usize {
        self.base.as_deref().map_or(0, |b| b.n_base)
    }

    /// Base snapshot residual rows not yet consumed by folds.
    fn base_res_rem(&self) -> usize {
        self.base.as_deref().map_or(0, |b| b.res_rows - self.base_res_off)
    }

    /// Quantized tokens folded privately, past the shared base. Private
    /// packed strides and destination group indices are relative to this.
    fn own_q(&self) -> usize {
        self.n_q - self.n_base()
    }

    /// Logical residual row `i` (of [`LayerCache::n_res`]): unconsumed base
    /// snapshot rows come first, then the private ring.
    fn res_row(&self, i: usize) -> (&[f32], &[f32]) {
        let hd = self.geo.n_heads * self.geo.d_head;
        let brem = self.base_res_rem();
        if i < brem {
            let b = self.base.as_deref().unwrap();
            let src = (self.base_res_off + i) * hd;
            (&b.res_k[src..src + hd], &b.res_v[src..src + hd])
        } else {
            let src = ((self.res_start + (i - brem)) % self.res_cap) * hd;
            (&self.res_k[src..src + hd], &self.res_v[src..src + hd])
        }
    }

    pub fn n_res(&self) -> usize {
        self.base_res_rem() + self.res_len
    }

    // -----------------------------------------------------------------
    // change tracking (module docs: equal version ⟹ identical state)
    // -----------------------------------------------------------------

    /// Monotonically bumped on every mutation.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Object identity: changes ONLY at construction, clone (snapshot
    /// restore) and [`LayerCache::invalidate`]. While it is stable the
    /// cache's history is linear — packed groups and residual rows are
    /// append-only — so a consumer that recorded (`packed_version`, `n_q`)
    /// may patch just the appended tail.
    pub fn ident_version(&self) -> u64 {
        self.ident_version
    }

    /// Packed-region stride identity (restride / replacement invalidates).
    pub fn layout_version(&self) -> u64 {
        self.layout_version
    }

    /// Packed-region content identity; with an unchanged `layout_version`,
    /// a newer value means folds appended groups `[seen_n_q/G, n_q/G)` and
    /// touched nothing below.
    pub fn packed_version(&self) -> u64 {
        self.packed_version
    }

    /// Residual-ring origin identity; while unchanged, the ring only grew
    /// at the tail, so rows `[0, seen_len)` are exactly as last observed.
    pub fn res_base_version(&self) -> u64 {
        self.res_base_version
    }

    /// Mark every region dirty. For code that mutates the public buffers
    /// directly instead of going through the append/fold API.
    pub fn invalidate(&mut self) {
        self.ident_version = next_version();
        self.version = next_version();
        self.layout_version = next_version();
        self.packed_version = next_version();
        self.res_base_version = next_version();
    }

    /// Total cached tokens (quantized + residual).
    pub fn n_tokens(&self) -> usize {
        self.n_q + self.n_res()
    }

    /// Allocated quantized-region capacity in tokens (page-aligned, ≤ T).
    pub fn q_capacity(&self) -> usize {
        self.q_cap
    }

    /// Allocated residual-ring capacity in tokens (page-aligned, ≤ R).
    pub fn res_capacity(&self) -> usize {
        self.res_cap
    }

    // -----------------------------------------------------------------
    // paged growth
    // -----------------------------------------------------------------

    /// Capacities after appending `count` tokens: exact page-rounded need,
    /// shared by the growth paths AND [`LayerCache::growth_bytes_for`] so
    /// prediction and allocation can never diverge.
    fn caps_for_append(&self, count: usize) -> (usize, usize) {
        let (g, r, t) = (self.geo.group, self.geo.residual, self.geo.max_ctx);
        // appends fold as late as possible: ceil(overflow / G) groups
        let folds = (self.n_res() + count).saturating_sub(r).div_ceil(g);
        // only privately folded groups need private packed pages
        let own_q2 = self.own_q() + folds * g;
        let q_t = if own_q2 > self.q_cap {
            page_target(own_q2, g, t - self.n_base())
        } else {
            self.q_cap
        };
        // private-ring occupancy: folds consume base snapshot rows first,
        // then the private ring, then batch tokens; appended tokens land
        // after the folds, so occupancy peaks at max(now, after)
        let from_base = (folds * g).min(self.base_res_rem());
        let from_own = (folds * g - from_base).min(self.res_len);
        let from_batch = folds * g - from_base - from_own;
        let res2 = self.res_len - from_own + (count - from_batch);
        let res_need = self.res_len.max(res2);
        let r_t = if res_need > self.res_cap {
            page_target(res_need, g, r)
        } else {
            self.res_cap
        };
        (q_t, r_t)
    }

    /// Allocation footprint at the given capacities (the closed form of
    /// [`LayerCache::capacity_bytes`]).
    fn bytes_at_caps(&self, q_cap: usize, res_cap: usize) -> usize {
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        let g2 = geo.g2();
        let mut total = 2 * res_cap * h * dh * 4; // fp32 ring, K and V
        if self.k_bits > 0 {
            total += h * rtn::packed_len(q_cap, self.k_bits) * dh;
            total += 2 * h * (q_cap / g) * dh * 4;
        } else {
            total += h * q_cap * dh * 4 + 2 * h * 4;
        }
        if self.v_bits > 0 {
            total += h * q_cap * rtn::packed_len(dh, self.v_bits);
            total += 2 * h * q_cap * (dh / g2) * 4;
        } else {
            total += h * q_cap * dh * 4 + 2 * h * 4;
        }
        total
    }

    /// Bytes this cache will newly allocate to absorb `count` appended
    /// tokens — exact, because growth is deterministic page-rounding.
    pub fn growth_bytes_for(&self, count: usize) -> usize {
        let (q_t, r_t) = self.caps_for_append(count);
        self.bytes_at_caps(q_t, r_t) - self.bytes_at_caps(self.q_cap, self.res_cap)
    }

    /// Grow the packed region (and its scale/zero params) to hold at least
    /// `need` **private** tokens (past any shared base), restriding each
    /// head's rows into the new buffers.
    fn ensure_q_cap(&mut self, need: usize) {
        if need <= self.q_cap {
            return;
        }
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        let g2 = geo.g2();
        let limit = geo.max_ctx - self.n_base();
        let new_cap = page_target(need, g, limit);
        assert!(new_cap >= need, "quantized region full (need {need} > T={limit})");
        let old = self.q_cap;
        // per-head restride: copy each head's old row into the wider layout
        fn restride<T: Copy + Default>(buf: &mut Vec<T>, h: usize, ob: usize, nb: usize) {
            let mut v = vec![T::default(); h * nb];
            for head in 0..h {
                v[head * nb..head * nb + ob].copy_from_slice(&buf[head * ob..(head + 1) * ob]);
            }
            *buf = v;
        }
        if self.k_bits > 0 {
            restride(&mut self.k_pk, h, rtn::packed_len(old, self.k_bits) * dh,
                     rtn::packed_len(new_cap, self.k_bits) * dh);
            let (op, np) = ((old / g) * dh, (new_cap / g) * dh);
            restride(&mut self.k_scales, h, op, np);
            restride(&mut self.k_zeros, h, op, np);
        } else {
            restride(&mut self.k_f32, h, old * dh, new_cap * dh);
        }
        if self.v_bits > 0 {
            let bpt = rtn::packed_len(dh, self.v_bits);
            restride(&mut self.v_pk, h, old * bpt, new_cap * bpt);
            let dg = dh / g2;
            restride(&mut self.v_scales, h, old * dg, new_cap * dg);
            restride(&mut self.v_zeros, h, old * dg, new_cap * dg);
        } else {
            restride(&mut self.v_f32, h, old * dh, new_cap * dh);
        }
        self.q_cap = new_cap;
        // strides changed: literals built against the old layout are dead
        self.version = next_version();
        self.layout_version = next_version();
    }

    /// Grow the residual ring to hold at least `need` tokens, compacting
    /// the occupied slots to the front of the new buffer.
    fn ensure_res_cap(&mut self, need: usize) {
        if need <= self.res_cap {
            return;
        }
        let geo = self.geo;
        let hd = geo.n_heads * geo.d_head;
        let new_cap = page_target(need, geo.group, geo.residual);
        assert!(new_cap >= need, "residual ring full (need {need} > R={})", geo.residual);
        let mut nk = vec![0f32; new_cap * hd];
        let mut nv = vec![0f32; new_cap * hd];
        for i in 0..self.res_len {
            let src = ((self.res_start + i) % self.res_cap) * hd;
            nk[i * hd..(i + 1) * hd].copy_from_slice(&self.res_k[src..src + hd]);
            nv[i * hd..(i + 1) * hd].copy_from_slice(&self.res_v[src..src + hd]);
        }
        self.res_k = nk;
        self.res_v = nv;
        self.res_start = 0;
        self.res_cap = new_cap;
        // compaction re-based the ring: previously observed rows moved
        self.version = next_version();
        self.res_base_version = next_version();
    }

    // -----------------------------------------------------------------
    // appends + folds
    // -----------------------------------------------------------------

    /// Append one token's K/V ([H, Dh] row-major each), folding if needed.
    /// Returns the number of folds performed (engine metrics).
    pub fn append_token(&mut self, k: &[f32], v: &[f32]) -> usize {
        let hd = self.geo.n_heads * self.geo.d_head;
        assert_eq!(k.len(), hd, "append_token: K row is not [H, Dh]");
        assert_eq!(v.len(), hd, "append_token: V row is not [H, Dh]");
        let mut folds = 0;
        while self.n_res() + 1 > self.geo.residual {
            self.fold_oldest_group();
            folds += 1;
        }
        self.ensure_res_cap(self.res_len + 1);
        let slot = (self.res_start + self.res_len) % self.res_cap;
        self.res_k[slot * hd..(slot + 1) * hd].copy_from_slice(k);
        self.res_v[slot * hd..(slot + 1) * hd].copy_from_slice(v);
        self.res_len += 1;
        self.version = next_version(); // tail append: base versions keep
        folds
    }

    /// Fold the oldest G residual tokens into the packed/quantized region.
    /// With a shared base attached, the oldest rows are the base snapshot's
    /// — they are *read* into the private packed tail and consumed by
    /// advancing `base_res_off`; the base itself is never written
    /// (copy-on-write: the only bytes materialized are the divergent ones).
    pub fn fold_oldest_group(&mut self) {
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        assert!(self.n_res() >= g, "fold needs at least one full group");
        assert!(self.n_q + g <= geo.max_ctx, "quantized region full");
        self.ensure_q_cap(self.own_q() + g);

        // gather oldest G logical rows per head into [G, Dh] scratch
        let mut kg = vec![0f32; g * dh];
        let mut vg = vec![0f32; g * dh];
        let gi = self.own_q() / g; // destination group index (own-relative)
        for head in 0..h {
            for t in 0..g {
                let (rk, rv) = self.res_row(t);
                kg[t * dh..(t + 1) * dh]
                    .copy_from_slice(&rk[head * dh..(head + 1) * dh]);
                vg[t * dh..(t + 1) * dh]
                    .copy_from_slice(&rv[head * dh..(head + 1) * dh]);
            }
            self.fold_k_head(head, gi, &kg);
            self.fold_v_head(head, gi, &vg);
        }
        let from_base = g.min(self.base_res_rem());
        self.base_res_off += from_base;
        let from_own = g - from_base;
        if from_own > 0 {
            self.res_start = (self.res_start + from_own) % self.res_cap;
            self.res_len -= from_own;
        }
        self.n_q += g;
        // packed region gained a tail group AND the ring origin advanced
        self.version = next_version();
        self.packed_version = next_version();
        self.res_base_version = next_version();
    }

    /// Append `count` tokens in one call (`ks`/`vs` are token-major
    /// [count, H, Dh] rows — `count` stacked [`LayerCache::append_token`]
    /// rows). Groups that must fold are folded straight from the combined
    /// ring + batch stream, so a prefill chunk performs its folds without
    /// routing every token through the residual ring first. Semantically
    /// identical to `count` sequential `append_token` calls (byte-identical
    /// packed state and residual contents; prop-tested). Returns the number
    /// of folds performed.
    pub fn append_tokens(&mut self, count: usize, ks: &[f32], vs: &[f32]) -> usize {
        let geo = self.geo;
        let (h, dh, g, r) = (geo.n_heads, geo.d_head, geo.group, geo.residual);
        let hd = h * dh;
        assert_eq!(ks.len(), count * hd, "append_tokens: K rows are not [count, H, Dh]");
        assert_eq!(vs.len(), count * hd, "append_tokens: V rows are not [count, H, Dh]");
        // sequential appends fold as late as possible: ceil(overflow / G)
        let folds = (self.n_res() + count).saturating_sub(r).div_ceil(g);
        assert!(self.n_q + folds * g <= geo.max_ctx, "quantized region full");
        self.ensure_q_cap(self.own_q() + folds * g);
        let mut consumed = 0; // batch tokens already folded
        let mut f = 0;
        while f < folds {
            if self.n_res() >= g {
                self.fold_oldest_group();
                f += 1;
            } else if self.n_res() == 0 {
                // residual fully drained: every remaining group comes
                // straight from the batch — fold them all in one
                // multi-head parallel pass (byte-identical to folding them
                // one by one; heads write disjoint buffer views)
                let nb = folds - f;
                self.fold_groups_batch(
                    nb,
                    &ks[consumed * hd..(consumed + nb * g) * hd],
                    &vs[consumed * hd..(consumed + nb * g) * hd],
                );
                // base rows were already consumed (n_res == 0) and the
                // ring is empty, so its origin is free to reset (safe even
                // when the ring has never been allocated, res_cap == 0)
                let base_rows = self.base.as_deref().map_or(0, |b| b.res_rows);
                self.base_res_off = base_rows;
                self.res_start = 0;
                self.res_len = 0;
                self.res_base_version = next_version();
                consumed += nb * g;
                f += nb;
            } else {
                // the group spans the residual remainder (base snapshot
                // rows + private ring) plus the batch head
                let from_cache = self.n_res();
                let take = g - from_cache;
                let mut kt = vec![0f32; g * hd];
                let mut vt = vec![0f32; g * hd];
                for t in 0..from_cache {
                    let (rk, rv) = self.res_row(t);
                    kt[t * hd..(t + 1) * hd].copy_from_slice(rk);
                    vt[t * hd..(t + 1) * hd].copy_from_slice(rv);
                }
                kt[from_cache * hd..].copy_from_slice(&ks[consumed * hd..(consumed + take) * hd]);
                vt[from_cache * hd..].copy_from_slice(&vs[consumed * hd..(consumed + take) * hd]);
                self.fold_group_rows(&kt, &vt);
                // residual fully drained: base rows are all consumed and the
                // ring origin is free to reset
                let base_rows = self.base.as_deref().map_or(0, |b| b.res_rows);
                self.base_res_off = base_rows;
                self.res_start = 0;
                self.res_len = 0;
                self.res_base_version = next_version();
                consumed += take;
                f += 1;
            }
        }
        // bulk-append the remaining batch tokens into the ring, in
        // contiguous runs up to the wrap point
        if consumed < count {
            self.ensure_res_cap(self.res_len + (count - consumed));
        }
        let rc = self.res_cap;
        let mut t = consumed;
        while t < count {
            let slot = (self.res_start + self.res_len + (t - consumed)) % rc;
            let run = (count - t).min(rc - slot);
            self.res_k[slot * hd..(slot + run) * hd]
                .copy_from_slice(&ks[t * hd..(t + run) * hd]);
            self.res_v[slot * hd..(slot + run) * hd]
                .copy_from_slice(&vs[t * hd..(t + run) * hd]);
            t += run;
        }
        self.res_len += count - consumed;
        debug_assert!(self.res_len <= r);
        self.version = next_version();
        folds
    }

    /// Fold one group given token-major [G, H, Dh] rows (shared by the
    /// batched append path; the ring fold gathers per head directly).
    fn fold_group_rows(&mut self, kt: &[f32], vt: &[f32]) {
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        assert!(self.n_q + g <= geo.max_ctx, "quantized region full");
        self.ensure_q_cap(self.own_q() + g);
        let hd = h * dh;
        let gi = self.own_q() / g;
        let mut kg = vec![0f32; g * dh];
        let mut vg = vec![0f32; g * dh];
        for head in 0..h {
            for t in 0..g {
                let src = t * hd + head * dh;
                kg[t * dh..(t + 1) * dh].copy_from_slice(&kt[src..src + dh]);
                vg[t * dh..(t + 1) * dh].copy_from_slice(&vt[src..src + dh]);
            }
            self.fold_k_head(head, gi, &kg);
            self.fold_v_head(head, gi, &vg);
        }
        self.n_q += g;
        self.version = next_version();
        self.packed_version = next_version();
    }

    /// `gi` is the destination group index **relative to the private packed
    /// region** (groups past any shared base).
    fn fold_k_head(&mut self, head: usize, gi: usize, kg: &[f32]) {
        let geo = self.geo;
        let (dh, g) = (geo.d_head, geo.group);
        let tc = self.q_cap; // allocated private capacity drives all strides
        let bits = self.k_bits;
        let t_pk = rtn::packed_len(tc, bits);
        let ng = tc / g;
        // head-relative views (the unused representation stays unsliced:
        // its buffer is empty or dummy-sized in the other bit mode)
        let (pk, f32s, scales, zeros): (&mut [u8], &mut [f32], &mut [f32], &mut [f32]) =
            if bits == 0 {
                (&mut [], &mut self.k_f32[head * tc * dh..(head + 1) * tc * dh], &mut [], &mut [])
            } else {
                (
                    &mut self.k_pk[head * t_pk * dh..(head + 1) * t_pk * dh],
                    &mut [],
                    &mut self.k_scales[head * ng * dh..(head + 1) * ng * dh],
                    &mut self.k_zeros[head * ng * dh..(head + 1) * ng * dh],
                )
            };
        fold_k_into(kg, gi, g, dh, bits, pk, f32s, scales, zeros);
    }

    /// `gi` is the destination group index relative to the private region.
    fn fold_v_head(&mut self, head: usize, gi: usize, vg: &[f32]) {
        let geo = self.geo;
        let (dh, g) = (geo.d_head, geo.group);
        let g2 = geo.g2();
        let tc = self.q_cap;
        let bits = self.v_bits;
        let bpt = rtn::packed_len(dh, bits);
        let dg = dh / g2;
        let (pk, f32s, scales, zeros): (&mut [u8], &mut [f32], &mut [f32], &mut [f32]) =
            if bits == 0 {
                (&mut [], &mut self.v_f32[head * tc * dh..(head + 1) * tc * dh], &mut [], &mut [])
            } else {
                (
                    &mut self.v_pk[head * tc * bpt..(head + 1) * tc * bpt],
                    &mut [],
                    &mut self.v_scales[head * tc * dg..(head + 1) * tc * dg],
                    &mut self.v_zeros[head * tc * dg..(head + 1) * tc * dg],
                )
            };
        fold_v_into(vg, gi, g, dh, g2, bits, pk, f32s, scales, zeros);
    }

    /// Fold `nfolds` consecutive groups straight from token-major
    /// [nfolds·G, H, Dh] batch rows, parallelized **across heads** on
    /// scoped worker threads ([`crate::util::par::scoped_map`]). Each head
    /// owns disjoint `&mut` views of the packed/param buffers
    /// ([`HeadFoldDst`]) and folds its `nfolds` groups sequentially with
    /// the exact same [`fold_k_into`]/[`fold_v_into`] calls the sequential
    /// path makes, so the resulting bytes are identical regardless of
    /// thread count. Precondition: the residual is empty (`n_res() == 0`)
    /// — the caller's fold budget then comes entirely from the batch.
    fn fold_groups_batch(&mut self, nfolds: usize, kt: &[f32], vt: &[f32]) {
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        let g2 = geo.g2();
        let hd = h * dh;
        debug_assert_eq!(self.n_res(), 0, "batch fold requires a drained residual");
        debug_assert_eq!(kt.len(), nfolds * g * hd);
        debug_assert_eq!(vt.len(), nfolds * g * hd);
        assert!(self.n_q + nfolds * g <= geo.max_ctx, "quantized region full");
        self.ensure_q_cap(self.own_q() + nfolds * g);
        let gi0 = self.own_q() / g; // first destination group (own-relative)
        let tc = self.q_cap;
        let (kb, vb) = (self.k_bits, self.v_bits);
        let t_pk = rtn::packed_len(tc, kb);
        let ng = tc / g;
        let bpt = rtn::packed_len(dh, vb);
        let dg = dh / g2;
        // carve every buffer into per-head views up front (inactive
        // representations become empty views), then bundle them per head
        let k_pk = head_views(&mut self.k_pk, t_pk * dh, h, kb > 0);
        let k_f32 = head_views(&mut self.k_f32, tc * dh, h, kb == 0);
        let k_scales = head_views(&mut self.k_scales, ng * dh, h, kb > 0);
        let k_zeros = head_views(&mut self.k_zeros, ng * dh, h, kb > 0);
        let v_pk = head_views(&mut self.v_pk, tc * bpt, h, vb > 0);
        let v_f32 = head_views(&mut self.v_f32, tc * dh, h, vb == 0);
        let v_scales = head_views(&mut self.v_scales, tc * dg, h, vb > 0);
        let v_zeros = head_views(&mut self.v_zeros, tc * dg, h, vb > 0);
        let mut tasks: Vec<HeadFoldDst> = Vec::with_capacity(h);
        for (head, views) in k_pk
            .into_iter()
            .zip(k_f32)
            .zip(k_scales)
            .zip(k_zeros)
            .zip(v_pk)
            .zip(v_f32)
            .zip(v_scales)
            .zip(v_zeros)
            .enumerate()
        {
            let (((((((k_pk, k_f32), k_scales), k_zeros), v_pk), v_f32), v_scales), v_zeros) =
                views;
            tasks.push(HeadFoldDst {
                head,
                k_pk,
                k_f32,
                k_scales,
                k_zeros,
                v_pk,
                v_f32,
                v_scales,
                v_zeros,
            });
        }
        crate::util::par::scoped_map(tasks, |mut dst: HeadFoldDst| {
            let head = dst.head;
            let mut kg = vec![0f32; g * dh];
            let mut vg = vec![0f32; g * dh];
            for f in 0..nfolds {
                for t in 0..g {
                    let src = (f * g + t) * hd + head * dh;
                    kg[t * dh..(t + 1) * dh].copy_from_slice(&kt[src..src + dh]);
                    vg[t * dh..(t + 1) * dh].copy_from_slice(&vt[src..src + dh]);
                }
                fold_k_into(
                    &kg,
                    gi0 + f,
                    g,
                    dh,
                    kb,
                    &mut dst.k_pk,
                    &mut dst.k_f32,
                    &mut dst.k_scales,
                    &mut dst.k_zeros,
                );
                fold_v_into(
                    &vg,
                    gi0 + f,
                    g,
                    dh,
                    g2,
                    vb,
                    &mut dst.v_pk,
                    &mut dst.v_f32,
                    &mut dst.v_scales,
                    &mut dst.v_zeros,
                );
            }
        });
        self.n_q += nfolds * g;
        self.version = next_version();
        self.packed_version = next_version();
    }

    // -----------------------------------------------------------------
    // in-place downshift (pressure-adaptive re-quantization)
    // -----------------------------------------------------------------

    /// Re-quantize the cold packed region to lower bit-widths **in place**
    /// and trim `q_capacity` to the page-rounded quantized length. The
    /// packed codes are re-quantized group-wise in the code domain
    /// ([`rtn::requant`]) — the cache is never rebuilt as floats. Residual
    /// ring rows are untouched: they are still fp32 and simply fold at the
    /// new widths from now on. Per side the transition must not add bits:
    /// `new == old` (no-op), `old == 0` (the fp32 region folds into a
    /// fresh packed region), or `0 < new < old`. Returns the allocation
    /// bytes freed; when called inside `CachePool::with_seq` the pool
    /// settles its accounting from the capacity delta automatically.
    pub fn downshift_groups(&mut self, new_kb: Bits, new_vb: Bits) -> usize {
        assert!(
            self.base.is_none(),
            "downshift_groups: attached caches share a read-only base; \
             the scheduler must pick an unattached victim"
        );
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        let g2 = geo.g2();
        let (old_kb, old_vb) = (self.k_bits, self.v_bits);
        assert!(
            new_kb == old_kb || old_kb == 0 || (new_kb > 0 && new_kb < old_kb),
            "downshift_groups: K transition {old_kb} -> {new_kb} adds bits"
        );
        assert!(
            new_vb == old_vb || old_vb == 0 || (new_vb > 0 && new_vb < old_vb),
            "downshift_groups: V transition {old_vb} -> {new_vb} adds bits"
        );
        let before = self.capacity_bytes();
        let new_cap = page_target(self.n_q, g, geo.max_ctx);
        debug_assert!(new_cap <= self.q_cap, "q_cap below page-rounded n_q");
        if new_kb == old_kb && new_vb == old_vb && new_cap == self.q_cap {
            return 0;
        }
        let n_groups = self.n_q / g;

        // --- K side: [H, Tc·kb/8, Dh] packed + per-channel params ---
        if new_kb != old_kb || new_cap != self.q_cap {
            if new_kb > 0 {
                let rows_new = rtn::packed_len(g, new_kb);
                let t_pk_new = rtn::packed_len(new_cap, new_kb);
                let ngn = new_cap / g;
                let mut pk = vec![0u8; h * t_pk_new * dh];
                let mut scales = vec![0f32; h * ngn * dh];
                let mut zeros = vec![0f32; h * ngn * dh];
                let mut params = vec![GroupParams { scale: 0.0, zero: 0.0 }; dh];
                for head in 0..h {
                    for gi in 0..n_groups {
                        let dst = head * t_pk_new * dh + gi * rows_new * dh;
                        let out = &mut pk[dst..dst + rows_new * dh];
                        if old_kb == 0 {
                            let src = head * self.q_cap * dh + gi * g * dh;
                            rtn::fold_k_group(
                                &self.k_f32[src..src + g * dh],
                                g, dh, new_kb, out, &mut params,
                            );
                        } else {
                            let rows_old = rtn::packed_len(g, old_kb);
                            let t_pk_old = rtn::packed_len(self.q_cap, old_kb);
                            let src = head * t_pk_old * dh + gi * rows_old * dh;
                            let pb = head * (self.q_cap / g) * dh + gi * dh;
                            let old_params: Vec<GroupParams> = (0..dh)
                                .map(|d| GroupParams {
                                    scale: self.k_scales[pb + d],
                                    zero: self.k_zeros[pb + d],
                                })
                                .collect();
                            rtn::requant::requant_k_group(
                                &self.k_pk[src..src + rows_old * dh],
                                &old_params,
                                g, dh, old_kb, new_kb, out, &mut params,
                            );
                        }
                        let pb = head * ngn * dh + gi * dh;
                        for d in 0..dh {
                            scales[pb + d] = params[d].scale;
                            zeros[pb + d] = params[d].zero;
                        }
                    }
                }
                self.k_pk = pk;
                self.k_scales = scales;
                self.k_zeros = zeros;
                self.k_f32 = vec![];
            } else {
                // fp32 -> fp32 with a pure capacity trim
                let mut f = vec![0f32; h * new_cap * dh];
                for head in 0..h {
                    let src = head * self.q_cap * dh;
                    let dst = head * new_cap * dh;
                    f[dst..dst + self.n_q * dh]
                        .copy_from_slice(&self.k_f32[src..src + self.n_q * dh]);
                }
                self.k_f32 = f;
            }
        }

        // --- V side: [H, Tc, Dh·vb/8] packed + per-token params ---
        if new_vb != old_vb || new_cap != self.q_cap {
            if new_vb > 0 {
                let bpt_new = rtn::packed_len(dh, new_vb);
                let dg = dh / g2;
                let mut pk = vec![0u8; h * new_cap * bpt_new];
                let mut scales = vec![0f32; h * new_cap * dg];
                let mut zeros = vec![0f32; h * new_cap * dg];
                let mut params =
                    vec![GroupParams { scale: 0.0, zero: 0.0 }; g * dg];
                for head in 0..h {
                    for gi in 0..n_groups {
                        let dst = head * new_cap * bpt_new + gi * g * bpt_new;
                        let out = &mut pk[dst..dst + g * bpt_new];
                        if old_vb == 0 {
                            let src = head * self.q_cap * dh + gi * g * dh;
                            rtn::fold_v_group(
                                &self.v_f32[src..src + g * dh],
                                g, dh, g2, new_vb, out, &mut params,
                            );
                        } else {
                            let bpt_old = rtn::packed_len(dh, old_vb);
                            let src = head * self.q_cap * bpt_old + gi * g * bpt_old;
                            let pb = head * self.q_cap * dg + gi * g * dg;
                            let old_params: Vec<GroupParams> = (0..g * dg)
                                .map(|i| GroupParams {
                                    scale: self.v_scales[pb + i],
                                    zero: self.v_zeros[pb + i],
                                })
                                .collect();
                            rtn::requant::requant_v_group(
                                &self.v_pk[src..src + g * bpt_old],
                                &old_params,
                                g, dh, g2, old_vb, new_vb, out, &mut params,
                            );
                        }
                        let pb = head * new_cap * dg + gi * g * dg;
                        for i in 0..g * dg {
                            scales[pb + i] = params[i].scale;
                            zeros[pb + i] = params[i].zero;
                        }
                    }
                }
                self.v_pk = pk;
                self.v_scales = scales;
                self.v_zeros = zeros;
                self.v_f32 = vec![];
            } else {
                let mut f = vec![0f32; h * new_cap * dh];
                for head in 0..h {
                    let src = head * self.q_cap * dh;
                    let dst = head * new_cap * dh;
                    f[dst..dst + self.n_q * dh]
                        .copy_from_slice(&self.v_f32[src..src + self.n_q * dh]);
                }
                self.v_f32 = f;
            }
        }

        self.q_cap = new_cap;
        self.k_bits = new_kb;
        self.v_bits = new_vb;
        // a downshift rewrites packed groups BELOW n_q — not an append —
        // so the linear-history promise behind ident_version is void:
        // re-stamp everything (full re-scatter on the next gather sync)
        self.invalidate();
        let after = self.capacity_bytes();
        debug_assert!(after <= before, "downshift must never grow the cache");
        before - after
    }

    /// Write the residual window into `out` laid out [H, R, Dh] (artifact
    /// layout), compacting the ring so occupied slots are [0, n_res).
    pub fn gather_residual(&self, out_k: &mut [f32], out_v: &mut [f32]) {
        self.copy_residual_rows(0, self.n_res(), out_k, out_v);
    }

    /// Write only logical residual rows `[lo, hi)` into the [H, R, Dh]
    /// artifact layout — the tail-patch primitive: while
    /// [`LayerCache::res_base_version`] is unchanged, rows below a
    /// previously observed length are untouched, so an incremental gather
    /// copies just the newly appended rows.
    pub fn copy_residual_rows(
        &self,
        lo: usize,
        hi: usize,
        out_k: &mut [f32],
        out_v: &mut [f32],
    ) {
        let geo = self.geo;
        let (h, dh, r) = (geo.n_heads, geo.d_head, geo.residual);
        debug_assert!(hi <= self.n_res());
        debug_assert_eq!(out_k.len(), h * r * dh);
        for slot in lo..hi {
            let (rk, rv) = self.res_row(slot);
            for head in 0..h {
                let src = head * dh;
                let dst = head * r * dh + slot * dh;
                out_k[dst..dst + dh].copy_from_slice(&rk[src..src + dh]);
                out_v[dst..dst + dh].copy_from_slice(&rv[src..src + dh]);
            }
        }
    }

    /// Reconstruct the full fp32 K cache [H, n_tokens, Dh] (analysis tools;
    /// dequantizes the packed region through the same rtn kernels).
    pub fn dequant_k_full(&self) -> Vec<f32> {
        self.dequant_full(true)
    }

    pub fn dequant_v_full(&self) -> Vec<f32> {
        self.dequant_full(false)
    }

    /// Select the buffers holding quantized group `gi` of the K (`is_k`) or
    /// V side: groups below `n_base` read the shared base at its exact
    /// strides, the rest read the private tail at `q_cap` strides. Returns
    /// `(packed, f32s, scales, zeros, stride_cap, local_group_index)` —
    /// shared by full dequantization and the packed attention path.
    #[allow(clippy::type_complexity)]
    fn packed_region(
        &self,
        is_k: bool,
        gi: usize,
    ) -> (&[u8], &[f32], &[f32], &[f32], usize, usize) {
        let n_base = self.n_base();
        if gi * self.geo.group < n_base {
            let b = self.base.as_deref().unwrap();
            if is_k {
                (&b.k_pk, &b.k_f32, &b.k_scales, &b.k_zeros, b.n_base, gi)
            } else {
                (&b.v_pk, &b.v_f32, &b.v_scales, &b.v_zeros, b.n_base, gi)
            }
        } else {
            let lgi = gi - n_base / self.geo.group;
            if is_k {
                (&self.k_pk, &self.k_f32, &self.k_scales, &self.k_zeros, self.q_cap, lgi)
            } else {
                (&self.v_pk, &self.v_f32, &self.v_scales, &self.v_zeros, self.q_cap, lgi)
            }
        }
    }

    fn dequant_full(&self, is_k: bool) -> Vec<f32> {
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        let g2 = geo.g2();
        let n = self.n_tokens();
        let mut out = vec![0f32; h * n * dh];
        let bits = if is_k { self.k_bits } else { self.v_bits };
        for head in 0..h {
            for gi in 0..self.n_q / g {
                let mut buf = vec![0f32; g * dh];
                let (pk, f32s, scales, zeros, tc, lgi) = self.packed_region(is_k, gi);
                if bits == 0 {
                    let src = head * tc * dh + lgi * g * dh;
                    buf.copy_from_slice(&f32s[src..src + g * dh]);
                } else if is_k {
                    let rows_pk = rtn::packed_len(g, bits);
                    let t_pk = rtn::packed_len(tc, bits);
                    let src = head * t_pk * dh + lgi * rows_pk * dh;
                    let ng = tc / g;
                    let pbase = head * ng * dh + lgi * dh;
                    let params: Vec<GroupParams> = (0..dh)
                        .map(|d| GroupParams {
                            scale: scales[pbase + d],
                            zero: zeros[pbase + d],
                        })
                        .collect();
                    rtn::unfold_k_group(&pk[src..src + rows_pk * dh],
                                        g, dh, bits, &params, &mut buf);
                } else {
                    let bpt = rtn::packed_len(dh, bits);
                    let dg = dh / g2;
                    let src = head * tc * bpt + lgi * g * bpt;
                    let pbase = head * tc * dg + lgi * g * dg;
                    let params: Vec<GroupParams> = (0..g * dg)
                        .map(|i| GroupParams {
                            scale: scales[pbase + i],
                            zero: zeros[pbase + i],
                        })
                        .collect();
                    rtn::unfold_v_group(&pk[src..src + g * bpt],
                                        g, dh, g2, bits, &params, &mut buf);
                }
                let dst = head * n * dh + gi * g * dh;
                out[dst..dst + g * dh].copy_from_slice(&buf);
            }
            // residual region (base snapshot rows first, then the ring)
            for slot in 0..self.n_res() {
                let (rk, rv) = self.res_row(slot);
                let res = if is_k { rk } else { rv };
                let dst = head * n * dh + (self.n_q + slot) * dh;
                out[dst..dst + dh]
                    .copy_from_slice(&res[head * dh..(head + 1) * dh]);
            }
        }
        out
    }

    /// Single-head decode attention straight from the cache: scores
    /// `q·K^T/√Dh`, softmax, and the `p·V` output — without ever
    /// materializing a dequantized K/V region. Quantized groups go through
    /// the [`rtn::attn_scores_k_group`] / [`rtn::attn_weighted_v_group`]
    /// dispatch (register-resident fused dequant under
    /// `ASYMKV_KERNELS=fused`, unfold-then-matmul otherwise — bit-identical
    /// either way); fp32 regions and the residual ring use the same
    /// canonical [`rtn::dot8`] / [`rtn::weighted_acc`] orders, so the
    /// result is bit-identical to attending over
    /// [`LayerCache::dequant_k_full`] / [`LayerCache::dequant_v_full`]
    /// rows (prop-tested below). Returns `(weights, output)`:
    /// the `n_tokens` softmax weights and the `Dh` output row.
    pub fn attend_head(&self, head: usize, q: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let geo = self.geo;
        let (dh, g) = (geo.d_head, geo.group);
        let g2 = geo.g2();
        assert!(head < geo.n_heads, "attend_head: head {head} out of range");
        assert_eq!(q.len(), dh, "attend_head: query row is not [Dh]");
        let n = self.n_tokens();
        let mut weights = vec![0f32; n];
        let mut out = vec![0f32; dh];
        if n == 0 {
            return (weights, out);
        }
        let (kb, vb) = (self.k_bits, self.v_bits);
        let mut params: Vec<GroupParams> = Vec::new(); // reused across groups
        // scores: quantized groups from packed codes, residual from fp32
        for gi in 0..self.n_q / g {
            let (pk, f32s, scales, zeros, tc, lgi) = self.packed_region(true, gi);
            let sc = &mut weights[gi * g..(gi + 1) * g];
            if kb == 0 {
                let src = head * tc * dh + lgi * g * dh;
                for (t, s) in sc.iter_mut().enumerate() {
                    *s = rtn::dot8(q, &f32s[src + t * dh..src + (t + 1) * dh]);
                }
            } else {
                let rows_pk = rtn::packed_len(g, kb);
                let t_pk = rtn::packed_len(tc, kb);
                let src = head * t_pk * dh + lgi * rows_pk * dh;
                let pbase = head * (tc / g) * dh + lgi * dh;
                params.clear();
                params.extend((0..dh).map(|d| GroupParams {
                    scale: scales[pbase + d],
                    zero: zeros[pbase + d],
                }));
                rtn::attn_scores_k_group(&pk[src..src + rows_pk * dh], g, dh, kb,
                                         &params, q, sc);
            }
        }
        for slot in 0..self.n_res() {
            let (rk, _) = self.res_row(slot);
            weights[self.n_q + slot] = rtn::dot8(q, &rk[head * dh..(head + 1) * dh]);
        }
        // scaled softmax (in place; max-subtracted for stability)
        let inv = 1.0 / (dh as f32).sqrt();
        let mut m = f32::NEG_INFINITY;
        for w in weights.iter_mut() {
            *w *= inv;
            if *w > m {
                m = *w;
            }
        }
        let mut denom = 0f32;
        for w in weights.iter_mut() {
            *w = (*w - m).exp();
            denom += *w;
        }
        for w in weights.iter_mut() {
            *w /= denom;
        }
        // output: groups accumulate in token order, then the residual tail
        for gi in 0..self.n_q / g {
            let (pk, f32s, scales, zeros, tc, lgi) = self.packed_region(false, gi);
            let p = &weights[gi * g..(gi + 1) * g];
            if vb == 0 {
                let src = head * tc * dh + lgi * g * dh;
                rtn::weighted_acc(p, &f32s[src..src + g * dh], g, dh, &mut out);
            } else {
                let bpt = rtn::packed_len(dh, vb);
                let dg = dh / g2;
                let src = head * tc * bpt + lgi * g * bpt;
                let pbase = head * tc * dg + lgi * g * dg;
                params.clear();
                params.extend((0..g * dg).map(|i| GroupParams {
                    scale: scales[pbase + i],
                    zero: zeros[pbase + i],
                }));
                rtn::attn_weighted_v_group(&pk[src..src + g * bpt], g, dh, g2, vb,
                                           &params, p, &mut out);
            }
        }
        for slot in 0..self.n_res() {
            let (_, rv) = self.res_row(slot);
            let w = weights[self.n_q + slot];
            rtn::weighted_acc(&[w], &rv[head * dh..(head + 1) * dh], 1, dh, &mut out);
        }
        (weights, out)
    }

    /// Bytes actually used by **privately held** cached tokens (packed data
    /// + params + residual ring). Shared-base bytes are excluded: the pool
    /// charges them once per unique base, not per attached sequence.
    pub fn used_bytes(&self) -> usize {
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        let g2 = geo.g2();
        let oq = self.own_q();
        let mut total = 0usize;
        // K side
        if self.k_bits > 0 {
            total += h * rtn::packed_len(oq, self.k_bits) * dh;
            total += 2 * h * (oq / g) * dh * 4;
        } else {
            total += h * oq * dh * 4;
        }
        // V side
        if self.v_bits > 0 {
            total += h * oq * rtn::packed_len(dh, self.v_bits);
            total += 2 * h * oq * (dh / g2) * 4;
        } else {
            total += h * oq * dh * 4;
        }
        // residual fp32 (both K and V)
        total += 2 * self.res_len * h * dh * 4;
        total
    }

    /// Resident allocation footprint: the pages actually allocated so far
    /// (grows with the sequence; at full growth this equals the old static
    /// full-context footprint).
    pub fn capacity_bytes(&self) -> usize {
        let total = self.k_pk.len()
            + self.v_pk.len()
            + 4 * (self.k_f32.len()
                + self.v_f32.len()
                + self.k_scales.len()
                + self.k_zeros.len()
                + self.v_scales.len()
                + self.v_zeros.len()
                + self.res_k.len()
                + self.res_v.len());
        debug_assert_eq!(total, self.bytes_at_caps(self.q_cap, self.res_cap));
        total
    }

    /// Footprint when fully grown (the pre-paging static allocation): what
    /// a worst-case full-context sequence will eventually be charged. For
    /// attached caches only the private tail can grow — the base region is
    /// never re-materialized privately.
    pub fn full_capacity_bytes(&self) -> usize {
        self.bytes_at_caps(self.geo.max_ctx - self.n_base(), self.geo.residual)
    }

    /// Freeze this cache's full state into a self-contained immutable
    /// [`LayerBase`]: the packed region re-strided to exact capacity
    /// (`cap == n_q`) and the residual window compacted, stitching through
    /// any base this cache is itself attached to — so extending a shared
    /// prefix and re-freezing yields a **chained** node (the radix-tree
    /// growth step) without borrowers ever knowing the provenance. The
    /// snapshot preserves the donor's exact fold state: an attached cache
    /// starts with identical `(n_q, n_res)` and therefore an identical
    /// future fold schedule, which (folds being lossy) is what makes
    /// attached decode bit-identical to an unshared replay.
    pub fn freeze_base(&self) -> LayerBase {
        let geo = self.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        let g2 = geo.g2();
        let hd = h * dh;
        let n_base = self.n_q;
        debug_assert_eq!(n_base % g, 0);
        let nb0 = self.n_base(); // groups below this come from our own base
        let n_res = self.n_res();

        // compacted residual snapshot, token-major like the live ring
        let mut res_k = vec![0f32; n_res * hd];
        let mut res_v = vec![0f32; n_res * hd];
        for i in 0..n_res {
            let (rk, rv) = self.res_row(i);
            res_k[i * hd..(i + 1) * hd].copy_from_slice(rk);
            res_v[i * hd..(i + 1) * hd].copy_from_slice(rv);
        }

        let ng = n_base / g;
        let base = self.base.as_deref();

        // K side at exact strides
        let (k_pk, k_f32, k_scales, k_zeros) = if self.k_bits > 0 {
            let bits = self.k_bits;
            let rows_pk = rtn::packed_len(g, bits);
            let t_pk = rtn::packed_len(n_base, bits);
            let mut pk = vec![0u8; h * t_pk * dh];
            let mut sc = vec![0f32; h * ng * dh];
            let mut zr = vec![0f32; h * ng * dh];
            for head in 0..h {
                for gi in 0..ng {
                    let (src_pk, src_sc, src_zr, tc, lgi) = if gi * g < nb0 {
                        let b = base.unwrap();
                        (&b.k_pk, &b.k_scales, &b.k_zeros, b.n_base, gi)
                    } else {
                        (&self.k_pk, &self.k_scales, &self.k_zeros,
                         self.q_cap, gi - nb0 / g)
                    };
                    let s_tpk = rtn::packed_len(tc, bits);
                    let src = head * s_tpk * dh + lgi * rows_pk * dh;
                    let dst = head * t_pk * dh + gi * rows_pk * dh;
                    pk[dst..dst + rows_pk * dh]
                        .copy_from_slice(&src_pk[src..src + rows_pk * dh]);
                    let spb = head * (tc / g) * dh + lgi * dh;
                    let dpb = head * ng * dh + gi * dh;
                    sc[dpb..dpb + dh].copy_from_slice(&src_sc[spb..spb + dh]);
                    zr[dpb..dpb + dh].copy_from_slice(&src_zr[spb..spb + dh]);
                }
            }
            (pk, vec![], sc, zr)
        } else {
            let mut f = vec![0f32; h * n_base * dh];
            for head in 0..h {
                for gi in 0..ng {
                    let (src_f, tc, lgi) = if gi * g < nb0 {
                        (&base.unwrap().k_f32, base.unwrap().n_base, gi)
                    } else {
                        (&self.k_f32, self.q_cap, gi - nb0 / g)
                    };
                    let src = head * tc * dh + lgi * g * dh;
                    let dst = head * n_base * dh + gi * g * dh;
                    f[dst..dst + g * dh].copy_from_slice(&src_f[src..src + g * dh]);
                }
            }
            (vec![], f, vec![0f32; h], vec![0f32; h])
        };

        // V side at exact strides
        let (v_pk, v_f32, v_scales, v_zeros) = if self.v_bits > 0 {
            let bits = self.v_bits;
            let bpt = rtn::packed_len(dh, bits);
            let dg = dh / g2;
            let mut pk = vec![0u8; h * n_base * bpt];
            let mut sc = vec![0f32; h * n_base * dg];
            let mut zr = vec![0f32; h * n_base * dg];
            for head in 0..h {
                for gi in 0..ng {
                    let (src_pk, src_sc, src_zr, tc, lgi) = if gi * g < nb0 {
                        let b = base.unwrap();
                        (&b.v_pk, &b.v_scales, &b.v_zeros, b.n_base, gi)
                    } else {
                        (&self.v_pk, &self.v_scales, &self.v_zeros,
                         self.q_cap, gi - nb0 / g)
                    };
                    let src = head * tc * bpt + lgi * g * bpt;
                    let dst = head * n_base * bpt + gi * g * bpt;
                    pk[dst..dst + g * bpt]
                        .copy_from_slice(&src_pk[src..src + g * bpt]);
                    let spb = head * tc * dg + lgi * g * dg;
                    let dpb = head * n_base * dg + gi * g * dg;
                    sc[dpb..dpb + g * dg].copy_from_slice(&src_sc[spb..spb + g * dg]);
                    zr[dpb..dpb + g * dg].copy_from_slice(&src_zr[spb..spb + g * dg]);
                }
            }
            (pk, vec![], sc, zr)
        } else {
            let mut f = vec![0f32; h * n_base * dh];
            for head in 0..h {
                for gi in 0..ng {
                    let (src_f, tc, lgi) = if gi * g < nb0 {
                        (&base.unwrap().v_f32, base.unwrap().n_base, gi)
                    } else {
                        (&self.v_f32, self.q_cap, gi - nb0 / g)
                    };
                    let src = head * tc * dh + lgi * g * dh;
                    let dst = head * n_base * dh + gi * g * dh;
                    f[dst..dst + g * dh].copy_from_slice(&src_f[src..src + g * dh]);
                }
            }
            (vec![], f, vec![0f32; h], vec![0f32; h])
        };

        LayerBase {
            id: next_version(),
            geo,
            k_bits: self.k_bits,
            v_bits: self.v_bits,
            n_base,
            k_pk,
            k_f32,
            k_scales,
            k_zeros,
            v_pk,
            v_f32,
            v_scales,
            v_zeros,
            res_rows: n_res,
            res_k,
            res_v,
        }
    }

    /// Rebuild a ROOT cache (no `base` link) from a frozen snapshot — the
    /// hibernation restore path, the inverse of [`LayerCache::freeze_base`].
    /// The snapshot's exact-stride packed region (capacity == `n_base`) is
    /// re-strided per head out to the page-rounded live capacity, and the
    /// compacted residual rows become the front of a fresh ring. The
    /// restored cache starts with identical `(n_q, n_res)`, and folds
    /// depend only on those logical counts — so its future fold schedule
    /// (and therefore its decode output, folds being lossy) is
    /// bit-identical to the donor's. Fresh version stamps: consumers that
    /// cached literals against the donor must not alias the restoree.
    pub fn from_frozen(base: &LayerBase) -> Self {
        let geo = base.geo;
        let (h, dh, g) = (geo.n_heads, geo.d_head, geo.group);
        let g2 = geo.g2();
        let hd = h * dh;
        let n_base = base.n_base;
        assert_eq!(n_base % g, 0, "from_frozen: snapshot not group-aligned");
        assert!(
            n_base <= geo.max_ctx && base.res_rows <= geo.residual,
            "from_frozen: snapshot exceeds geometry"
        );
        let q_cap = page_target(n_base, g, geo.max_ctx);
        let ng = n_base / g;

        // K side: exact snapshot strides → page-rounded live strides
        let (k_pk, k_f32, k_scales, k_zeros) = if base.k_bits > 0 {
            let bits = base.k_bits;
            let s_tpk = rtn::packed_len(n_base, bits);
            let d_tpk = rtn::packed_len(q_cap, bits);
            let ngc = q_cap / g;
            let mut pk = vec![0u8; h * d_tpk * dh];
            let mut sc = vec![0f32; h * ngc * dh];
            let mut zr = vec![0f32; h * ngc * dh];
            for head in 0..h {
                let dst = head * d_tpk * dh;
                pk[dst..dst + s_tpk * dh].copy_from_slice(
                    &base.k_pk[head * s_tpk * dh..(head + 1) * s_tpk * dh],
                );
                let (src, dst) = (head * ng * dh, head * ngc * dh);
                sc[dst..dst + ng * dh]
                    .copy_from_slice(&base.k_scales[src..src + ng * dh]);
                zr[dst..dst + ng * dh]
                    .copy_from_slice(&base.k_zeros[src..src + ng * dh]);
            }
            (pk, vec![], sc, zr)
        } else {
            let mut f = vec![0f32; h * q_cap * dh];
            for head in 0..h {
                let dst = head * q_cap * dh;
                f[dst..dst + n_base * dh].copy_from_slice(
                    &base.k_f32[head * n_base * dh..(head + 1) * n_base * dh],
                );
            }
            (vec![], f, vec![0f32; h], vec![0f32; h])
        };

        // V side: token-major per head, same re-stride
        let (v_pk, v_f32, v_scales, v_zeros) = if base.v_bits > 0 {
            let bpt = rtn::packed_len(dh, base.v_bits);
            let dg = dh / g2;
            let mut pk = vec![0u8; h * q_cap * bpt];
            let mut sc = vec![0f32; h * q_cap * dg];
            let mut zr = vec![0f32; h * q_cap * dg];
            for head in 0..h {
                let dst = head * q_cap * bpt;
                pk[dst..dst + n_base * bpt].copy_from_slice(
                    &base.v_pk[head * n_base * bpt..(head + 1) * n_base * bpt],
                );
                let (src, dst) = (head * n_base * dg, head * q_cap * dg);
                sc[dst..dst + n_base * dg]
                    .copy_from_slice(&base.v_scales[src..src + n_base * dg]);
                zr[dst..dst + n_base * dg]
                    .copy_from_slice(&base.v_zeros[src..src + n_base * dg]);
            }
            (pk, vec![], sc, zr)
        } else {
            let mut f = vec![0f32; h * q_cap * dh];
            for head in 0..h {
                let dst = head * q_cap * dh;
                f[dst..dst + n_base * dh].copy_from_slice(
                    &base.v_f32[head * n_base * dh..(head + 1) * n_base * dh],
                );
            }
            (vec![], f, vec![0f32; h], vec![0f32; h])
        };

        // residual: compacted snapshot rows → front of a fresh ring
        let res_cap = page_target(base.res_rows, g, geo.residual);
        let mut res_k = vec![0f32; res_cap * hd];
        let mut res_v = vec![0f32; res_cap * hd];
        res_k[..base.res_rows * hd]
            .copy_from_slice(&base.res_k[..base.res_rows * hd]);
        res_v[..base.res_rows * hd]
            .copy_from_slice(&base.res_v[..base.res_rows * hd]);

        Self {
            geo,
            k_bits: base.k_bits,
            v_bits: base.v_bits,
            ident_version: next_version(),
            version: next_version(),
            layout_version: next_version(),
            packed_version: next_version(),
            res_base_version: next_version(),
            n_q: n_base,
            q_cap,
            k_pk,
            k_f32,
            k_scales,
            k_zeros,
            v_pk,
            v_f32,
            v_scales,
            v_zeros,
            res_k,
            res_v,
            res_cap,
            res_start: 0,
            res_len: base.res_rows,
            base: None,
            base_res_off: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn geo() -> CacheGeometry {
        CacheGeometry { n_heads: 2, max_ctx: 128, d_head: 32, group: 32, residual: 64 }
    }

    fn tok(g: &mut Gen, hd: usize) -> (Vec<f32>, Vec<f32>) {
        (g.vec_normal(hd, 1.0), g.vec_normal(hd, 1.0))
    }

    #[test]
    fn append_fold_counts() {
        let mut c = LayerCache::new(geo(), 2, 1);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(1) };
        let hd = 2 * 32;
        for i in 0..64 {
            let (k, v) = tok(&mut g, hd);
            assert_eq!(c.append_token(&k, &v), 0, "no fold before R at {i}");
        }
        assert_eq!(c.n_res(), 64);
        assert_eq!(c.n_q, 0);
        let (k, v) = tok(&mut g, hd);
        assert_eq!(c.append_token(&k, &v), 1); // first fold
        assert_eq!(c.n_q, 32);
        assert_eq!(c.n_res(), 33);
        assert_eq!(c.n_tokens(), 65);
    }

    #[test]
    fn float_path_is_lossless() {
        let mut c = LayerCache::new(geo(), 0, 0);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(2) };
        let hd = 2 * 32;
        let mut ks = vec![];
        for _ in 0..100 {
            let (k, v) = tok(&mut g, hd);
            ks.push(k.clone());
            c.append_token(&k, &v);
        }
        let full = c.dequant_k_full(); // [H, 100, Dh]
        for (t, k) in ks.iter().enumerate() {
            for head in 0..2 {
                let got = &full[head * 100 * 32 + t * 32..][..32];
                let want = &k[head * 32..(head + 1) * 32];
                assert_eq!(got, want, "token {t} head {head}");
            }
        }
    }

    #[test]
    fn quantized_path_error_bounded_prop() {
        check("cache_quant_bound", 10, |g: &mut Gen| {
            let bits = *g.pick(&[1u8, 2, 4]);
            let mut c = LayerCache::new(geo(), bits, bits);
            let hd = 2 * 32;
            let n = g.usize_in(70, 120);
            let mut ks = vec![];
            for _ in 0..n {
                let (k, v) = tok(g, hd);
                ks.push(k.clone());
                c.append_token(&k, &v);
            }
            let full = c.dequant_k_full();
            let nt = c.n_tokens();
            if nt != n {
                return Err(format!("token count {nt} != {n}"));
            }
            // max error over quantized region bounded by max scale/2
            let max_scale = c
                .k_scales
                .iter()
                .fold(0f32, |a, &b| a.max(b));
            for t in 0..c.n_q {
                for head in 0..2 {
                    for d in 0..32 {
                        let got = full[head * nt * 32 + t * 32 + d];
                        let want = ks[t][head * 32 + d];
                        if (got - want).abs() > max_scale * 0.5 + 1e-4 {
                            return Err(format!(
                                "err at t={t} h={head} d={d}: {got} vs {want}"
                            ));
                        }
                    }
                }
            }
            // residual region must be exact
            for t in c.n_q..nt {
                for head in 0..2 {
                    let got = &full[head * nt * 32 + t * 32..][..32];
                    let want = &ks[t][head * 32..(head + 1) * 32];
                    if got != want {
                        return Err(format!("residual not exact at {t}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn used_bytes_monotone_and_below_capacity() {
        let mut c = LayerCache::new(geo(), 2, 2);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(3) };
        let hd = 2 * 32;
        let first = {
            let (k, v) = tok(&mut g, hd);
            c.append_token(&k, &v);
            c.used_bytes()
        };
        let mut prev = first;
        for _ in 0..99 {
            let (k, v) = tok(&mut g, hd);
            let folds = c.append_token(&k, &v);
            let used = c.used_bytes();
            // between folds usage grows strictly; a fold converts 32 fp32
            // residual tokens into packed form, which may shrink usage
            if folds == 0 {
                assert!(used > prev, "usage must grow on plain append");
            }
            prev = used;
            assert!(used <= c.capacity_bytes());
        }
        assert!(prev > first);
    }

    #[test]
    fn bits_ordering_in_used_bytes() {
        // same token stream: 1-bit cache uses less memory than 2-bit than fp
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(4) };
        let hd = 2 * 32;
        let toks: Vec<(Vec<f32>, Vec<f32>)> = (0..100).map(|_| tok(&mut g, hd)).collect();
        let mut used = vec![];
        for bits in [1u8, 2, 0] {
            let mut c = LayerCache::new(geo(), bits, bits);
            for (k, v) in &toks {
                c.append_token(k, v);
            }
            used.push(c.used_bytes());
        }
        assert!(used[0] < used[1] && used[1] < used[2]);
    }

    #[test]
    fn append_tokens_matches_sequential_prop() {
        check("append_tokens_eq", 20, |g: &mut Gen| {
            let bits = *g.pick(&[0u8, 1, 2, 4]);
            let mut seq = LayerCache::new(geo(), bits, bits);
            let mut bat = LayerCache::new(geo(), bits, bits);
            let hd = 2 * 32;
            let mut total = 0usize;
            let mut folds_seq = 0;
            let mut folds_bat = 0;
            // several batches of varying size, including ones larger than R
            for _ in 0..g.usize_in(1, 4) {
                let count = g.usize_in(0, 90);
                if total + count > 128 {
                    break;
                }
                total += count;
                let ks = g.vec_normal(count * hd, 1.0);
                let vs = g.vec_normal(count * hd, 1.0);
                for t in 0..count {
                    folds_seq +=
                        seq.append_token(&ks[t * hd..(t + 1) * hd], &vs[t * hd..(t + 1) * hd]);
                }
                folds_bat += bat.append_tokens(count, &ks, &vs);
            }
            if folds_seq != folds_bat {
                return Err(format!("fold count diverges: {folds_seq} vs {folds_bat}"));
            }
            if seq.n_q != bat.n_q || seq.n_res() != bat.n_res() {
                return Err(format!(
                    "state diverges: n_q {} vs {}, n_res {} vs {}",
                    seq.n_q, bat.n_q, seq.n_res(), bat.n_res()
                ));
            }
            // paged growth must be deterministic regardless of granularity
            if seq.q_capacity() != bat.q_capacity()
                || seq.res_capacity() != bat.res_capacity()
            {
                return Err(format!(
                    "capacity diverges: q {} vs {}, res {} vs {}",
                    seq.q_capacity(), bat.q_capacity(),
                    seq.res_capacity(), bat.res_capacity()
                ));
            }
            if seq.k_pk != bat.k_pk || seq.v_pk != bat.v_pk {
                return Err("packed bytes diverge".into());
            }
            if seq.k_scales != bat.k_scales || seq.v_scales != bat.v_scales
                || seq.k_zeros != bat.k_zeros || seq.v_zeros != bat.v_zeros
            {
                return Err("group params diverge".into());
            }
            // residual ring contents must agree after compaction
            if seq.dequant_k_full() != bat.dequant_k_full()
                || seq.dequant_v_full() != bat.dequant_v_full()
            {
                return Err("reconstructed cache diverges".into());
            }
            Ok(())
        });
    }

    #[test]
    fn append_tokens_batch_larger_than_ring() {
        // one call appending far more tokens than R must fold straight from
        // the batch without ever overfilling the ring
        let mut c = LayerCache::new(geo(), 2, 2);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(9) };
        let hd = 2 * 32;
        let count = 128; // R = 64, G = 32
        let ks = g.vec_normal(count * hd, 1.0);
        let vs = g.vec_normal(count * hd, 1.0);
        let folds = c.append_tokens(count, &ks, &vs);
        assert_eq!(folds, 2);
        assert_eq!(c.n_q, 64);
        assert_eq!(c.n_res(), 64);
        assert_eq!(c.n_tokens(), 128);
    }

    #[test]
    fn attend_head_matches_dequant_reference_prop() {
        // packed attention must be bit-identical to the same canonical
        // dot8/softmax/weighted_acc sequence over the dequantized rows, for
        // every bit mode (incl. fp32 sides) and in whatever kernel mode the
        // env selects (the dispatch tiers are byte/bit-identical)
        check("attend_head_eq", 10, |g: &mut Gen| {
            let kb = *g.pick(&[1u8, 2, 4, 8, 0]);
            let vb = *g.pick(&[1u8, 2, 4, 8, 0]);
            let mut c = LayerCache::new(geo(), kb, vb);
            let (hd, dh) = (2 * 32, 32);
            let n = g.usize_in(1, 120);
            let ks = g.vec_normal(n * hd, 1.0);
            let vs = g.vec_normal(n * hd, 1.0);
            c.append_tokens(n, &ks, &vs);
            let nt = c.n_tokens();
            let kf = c.dequant_k_full(); // [H, nt, Dh]
            let vf = c.dequant_v_full();
            for head in 0..2 {
                let q = g.vec_normal(dh, 1.0);
                let mut want_w = vec![0f32; nt];
                for (t, w) in want_w.iter_mut().enumerate() {
                    *w = rtn::dot8(&q, &kf[head * nt * dh + t * dh..][..dh]);
                }
                let inv = 1.0 / (dh as f32).sqrt();
                let mut m = f32::NEG_INFINITY;
                for w in want_w.iter_mut() {
                    *w *= inv;
                    if *w > m {
                        m = *w;
                    }
                }
                let mut denom = 0f32;
                for w in want_w.iter_mut() {
                    *w = (*w - m).exp();
                    denom += *w;
                }
                for w in want_w.iter_mut() {
                    *w /= denom;
                }
                let mut want_o = vec![0f32; dh];
                rtn::weighted_acc(
                    &want_w, &vf[head * nt * dh..(head + 1) * nt * dh], nt, dh, &mut want_o,
                );
                let (got_w, got_o) = c.attend_head(head, &q);
                for t in 0..nt {
                    if got_w[t].to_bits() != want_w[t].to_bits() {
                        return Err(format!(
                            "weight t={t} head={head} kb={kb} vb={vb} n={n}: {} vs {}",
                            got_w[t], want_w[t]
                        ));
                    }
                }
                for d in 0..dh {
                    if got_o[d].to_bits() != want_o[d].to_bits() {
                        return Err(format!(
                            "out d={d} head={head} kb={kb} vb={vb} n={n}: {} vs {}",
                            got_o[d], want_o[d]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gather_residual_compacts_ring() {
        let mut c = LayerCache::new(geo(), 2, 2);
        let hd = 2 * 32;
        // push 70 tokens with identifiable values
        for i in 0..70 {
            let k = vec![i as f32; hd];
            let v = vec![-(i as f32); hd];
            c.append_token(&k, &v);
        }
        // 70 = 32 folded + 38 residual; oldest residual token is #32
        assert_eq!(c.n_q, 32);
        assert_eq!(c.n_res(), 38);
        let (h, r, dh) = (2, 64, 32);
        let mut out_k = vec![0f32; h * r * dh];
        let mut out_v = vec![0f32; h * r * dh];
        c.gather_residual(&mut out_k, &mut out_v);
        for slot in 0..38 {
            assert_eq!(out_k[slot * dh], (32 + slot) as f32, "slot {slot}");
            assert_eq!(out_v[slot * dh], -((32 + slot) as f32));
        }
    }

    // ---------------- paged allocation ----------------

    #[test]
    fn fresh_cache_allocates_nothing() {
        for bits in [0u8, 1, 2, 4] {
            let c = LayerCache::new(geo(), bits, bits);
            assert_eq!(c.q_capacity(), 0);
            assert_eq!(c.res_capacity(), 0);
            // fp32 paths keep their fixed dummy scale rows; that is all
            let dummy = if bits == 0 { 4 * 2 * 2 * 2 } else { 0 };
            assert_eq!(c.capacity_bytes(), dummy, "bits={bits}");
            assert!(c.capacity_bytes() < c.full_capacity_bytes());
        }
    }

    #[test]
    fn growth_is_page_aligned_and_lazy() {
        let mut c = LayerCache::new(geo(), 2, 2);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(11) };
        let hd = 2 * 32;
        let mut prev_cap = 0usize;
        for i in 0..128 {
            let (k, v) = tok(&mut g, hd);
            c.append_token(&k, &v);
            assert_eq!(c.res_capacity() % 32, 0, "ring pages are G-aligned");
            assert_eq!(c.q_capacity() % 32, 0, "packed pages are G-aligned");
            assert!(c.res_capacity() >= c.n_res());
            assert!(c.q_capacity() >= c.n_q);
            // lazy: never allocate a ring beyond one page over the need
            assert!(c.res_capacity() <= (c.n_res().div_ceil(32)) * 32);
            assert!(c.capacity_bytes() >= prev_cap, "capacity never shrinks at {i}");
            prev_cap = c.capacity_bytes();
        }
        // fully grown at max context
        assert_eq!(c.q_capacity(), 64);
        assert_eq!(c.res_capacity(), 64);
        assert!(c.capacity_bytes() < c.full_capacity_bytes());
    }

    #[test]
    fn growth_bytes_prediction_is_exact_prop() {
        check("paged_growth_exact", 30, |g: &mut Gen| {
            let bits = *g.pick(&[0u8, 1, 2, 4]);
            let mut c = LayerCache::new(geo(), bits, bits);
            let hd = 2 * 32;
            let mut total = 0usize;
            for _ in 0..g.usize_in(1, 4) {
                let count = g.usize_in(0, 70);
                if total + count > 128 {
                    break;
                }
                total += count;
                let predicted = c.growth_bytes_for(count);
                let before = c.capacity_bytes();
                let ks = g.vec_normal(count * hd, 1.0);
                let vs = g.vec_normal(count * hd, 1.0);
                c.append_tokens(count, &ks, &vs);
                let grown = c.capacity_bytes() - before;
                if grown != predicted {
                    return Err(format!(
                        "predicted {predicted}B but grew {grown}B at n={} count={count}",
                        c.n_tokens() - count
                    ));
                }
            }
            Ok(())
        });
    }

    // ---------------- change tracking ----------------

    #[test]
    fn versions_track_regions_precisely() {
        let mut c = LayerCache::new(geo(), 2, 2); // R=64, G=32
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(21) };
        let hd = 2 * 32;
        let (v0, l0, p0, b0) = (
            c.version(), c.layout_version(), c.packed_version(), c.res_base_version(),
        );
        let id0 = c.ident_version();
        // a plain append bumps version + res base only when the ring GROWS
        // a page; within an allocated page it is a pure tail write
        let (k, v) = tok(&mut g, hd);
        c.append_token(&k, &v);
        assert_ne!(c.version(), v0);
        assert_eq!(c.layout_version(), l0, "append must not invalidate layout");
        assert_eq!(c.packed_version(), p0, "append must not touch packed region");
        // first append allocated the first ring page (origin compacted)
        assert_ne!(c.res_base_version(), b0);
        let b1 = c.res_base_version();
        let p1 = c.packed_version();
        for _ in 0..31 {
            let (k, v) = tok(&mut g, hd);
            c.append_token(&k, &v);
        }
        assert_eq!(c.res_base_version(), b1, "in-page appends keep the ring base");
        assert_eq!(c.packed_version(), p1);
        // force a fold: packed content AND ring base change
        for _ in 0..33 {
            let (k, v) = tok(&mut g, hd);
            c.append_token(&k, &v);
        }
        assert!(c.n_q > 0, "fold must have happened");
        assert_ne!(c.packed_version(), p1);
        assert_ne!(c.res_base_version(), b1);
        // the fold's ensure_q_cap allocated the first packed page
        assert_ne!(c.layout_version(), l0);

        // a fold WITHIN already-allocated capacity (the fully-grown steady
        // state) bumps packed but keeps the stride layout
        let mut c2 = LayerCache::new(geo(), 2, 2);
        c2.ensure_q_cap(128);
        c2.ensure_res_cap(64);
        for _ in 0..64 {
            let (k, v) = tok(&mut g, hd);
            c2.append_token(&k, &v);
        }
        let (l2, p2) = (c2.layout_version(), c2.packed_version());
        let (k, v) = tok(&mut g, hd);
        c2.append_token(&k, &v); // folds (ring full), capacity pre-grown
        assert!(c2.n_q > 0);
        assert_ne!(c2.packed_version(), p2);
        assert_eq!(c2.layout_version(), l2, "in-capacity fold keeps strides");
        // object identity survives every append / fold / growth...
        assert_eq!(c.ident_version(), id0, "mutations keep object identity");
        // ...and only invalidate (or clone) re-stamps it, with everything else
        let before = (c.layout_version(), c.packed_version(), c.res_base_version());
        c.invalidate();
        assert_ne!(c.ident_version(), id0);
        assert_ne!(c.layout_version(), before.0);
        assert_ne!(c.packed_version(), before.1);
        assert_ne!(c.res_base_version(), before.2);
    }

    #[test]
    fn clone_restamps_every_version() {
        // a snapshot restore must never be patch-compatible with literals
        // built from the live cache (or any other cache): clones get fresh
        // globally-unique versions even though their bytes are identical
        let mut c = LayerCache::new(geo(), 1, 2);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(22) };
        let hd = 2 * 32;
        for _ in 0..40 {
            let (k, v) = tok(&mut g, hd);
            c.append_token(&k, &v);
        }
        let snap = c.clone();
        assert_ne!(snap.ident_version(), c.ident_version());
        assert_ne!(snap.version(), c.version());
        assert_ne!(snap.layout_version(), c.layout_version());
        assert_ne!(snap.packed_version(), c.packed_version());
        assert_ne!(snap.res_base_version(), c.res_base_version());
        // ...while the contents are byte-identical
        assert_eq!(snap.dequant_k_full(), c.dequant_k_full());
        assert_eq!(snap.dequant_v_full(), c.dequant_v_full());
    }

    #[test]
    fn copy_residual_rows_patches_tail() {
        let mut c = LayerCache::new(geo(), 2, 2);
        let hd = 2 * 32;
        for i in 0..10 {
            c.append_token(&vec![i as f32; hd], &vec![-(i as f32); hd]);
        }
        let (h, r, dh) = (2, 64, 32);
        let mut full_k = vec![0f32; h * r * dh];
        let mut full_v = vec![0f32; h * r * dh];
        c.gather_residual(&mut full_k, &mut full_v);
        // rebuild the same buffer from two partial copies
        let mut part_k = vec![0f32; h * r * dh];
        let mut part_v = vec![0f32; h * r * dh];
        c.copy_residual_rows(0, 6, &mut part_k, &mut part_v);
        c.copy_residual_rows(6, 10, &mut part_k, &mut part_v);
        assert_eq!(part_k, full_k);
        assert_eq!(part_v, full_v);
    }

    // ---------------- in-place downshift ----------------

    #[test]
    fn downshift_matches_refold_and_frees_bytes() {
        let mut c = LayerCache::new(geo(), 4, 4);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(31) };
        let hd = 2 * 32;
        for _ in 0..100 {
            let (k, v) = tok(&mut g, hd);
            c.append_token(&k, &v);
        }
        assert_eq!(c.n_q, 64);
        let n = c.n_tokens();
        let before_cap = c.capacity_bytes();
        let before_k = c.dequant_k_full();
        let before_v = c.dequant_v_full();
        let id0 = c.ident_version();

        let freed = c.downshift_groups(2, 1);
        assert!(freed > 0, "4->2/1 downshift must free packed bytes");
        assert_eq!(c.capacity_bytes(), before_cap - freed);
        assert_eq!((c.k_bits, c.v_bits), (2, 1));
        assert_eq!((c.n_q, c.q_capacity()), (64, 64));
        assert_ne!(c.ident_version(), id0, "non-append mutation re-stamps identity");

        let after_k = c.dequant_k_full();
        let after_v = c.dequant_v_full();
        let (gg, dh, g2) = (32usize, 32usize, 32usize);
        // residual rows are untouched — bitwise equal
        for head in 0..2 {
            for t in c.n_q..n {
                assert_eq!(
                    &after_k[head * n * dh + t * dh..][..dh],
                    &before_k[head * n * dh + t * dh..][..dh],
                    "residual K must be untouched"
                );
                assert_eq!(
                    &after_v[head * n * dh + t * dh..][..dh],
                    &before_v[head * n * dh + t * dh..][..dh],
                    "residual V must be untouched"
                );
            }
        }
        // quantized region: exactly the refold of the old reconstruction
        // at the new widths (the in-place requant is byte-equivalent to
        // unfold@old + fold@new)
        for head in 0..2 {
            for gi in 0..c.n_q / gg {
                let mut kg = vec![0f32; gg * dh];
                let mut vg = vec![0f32; gg * dh];
                for t in 0..gg {
                    let src = head * n * dh + (gi * gg + t) * dh;
                    kg[t * dh..(t + 1) * dh].copy_from_slice(&before_k[src..src + dh]);
                    vg[t * dh..(t + 1) * dh].copy_from_slice(&before_v[src..src + dh]);
                }
                let mut pk = vec![0u8; rtn::packed_len(gg, 2) * dh];
                let mut params = vec![GroupParams { scale: 0.0, zero: 0.0 }; dh];
                rtn::fold_k_group(&kg, gg, dh, 2, &mut pk, &mut params);
                let mut want = vec![0f32; gg * dh];
                rtn::unfold_k_group(&pk, gg, dh, 2, &params, &mut want);
                for t in 0..gg {
                    for d in 0..dh {
                        assert_eq!(
                            after_k[head * n * dh + (gi * gg + t) * dh + d],
                            want[t * dh + d],
                            "K refold equivalence head={head} gi={gi} t={t} d={d}"
                        );
                    }
                }
                let mut pv = vec![0u8; gg * rtn::packed_len(dh, 1)];
                let mut vparams =
                    vec![GroupParams { scale: 0.0, zero: 0.0 }; gg * (dh / g2)];
                rtn::fold_v_group(&vg, gg, dh, g2, 1, &mut pv, &mut vparams);
                rtn::unfold_v_group(&pv, gg, dh, g2, 1, &vparams, &mut want);
                for t in 0..gg {
                    for d in 0..dh {
                        assert_eq!(
                            after_v[head * n * dh + (gi * gg + t) * dh + d],
                            want[t * dh + d],
                            "V refold equivalence head={head} gi={gi} t={t} d={d}"
                        );
                    }
                }
            }
        }
        // the cache stays fully functional at the new widths
        for _ in 0..40 {
            let (k, v) = tok(&mut g, hd);
            c.append_token(&k, &v);
        }
        assert_eq!(c.n_tokens(), 140);
        assert_eq!(c.n_q, 96);
        assert!(c.capacity_bytes() > 0); // internal bytes_at_caps consistency
    }

    #[test]
    fn downshift_from_fp32_quantizes_cold_region() {
        let mut c = LayerCache::new(geo(), 0, 0);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(32) };
        let hd = 2 * 32;
        let mut ks = vec![];
        for _ in 0..100 {
            let (k, v) = tok(&mut g, hd);
            ks.push(k.clone());
            c.append_token(&k, &v);
        }
        assert_eq!(c.n_q, 64);
        let freed = c.downshift_groups(2, 2);
        assert!(freed > 0, "fp32 -> 2-bit must free most of the cold region");
        assert_eq!((c.k_bits, c.v_bits), (2, 2));
        // dummy fp32 param rows were replaced by real per-group params
        assert_eq!(c.k_scales.len(), 2 * (64 / 32) * 32);
        assert!(c.k_f32.is_empty());
        // quantized region error bounded by the new scales; residual exact
        let n = c.n_tokens();
        let full = c.dequant_k_full();
        let max_scale = c.k_scales.iter().fold(0f32, |a, &b| a.max(b));
        for head in 0..2 {
            for (t, k) in ks.iter().enumerate() {
                for d in 0..32 {
                    let got = full[head * n * 32 + t * 32 + d];
                    let want = k[head * 32 + d];
                    let tol = if t < c.n_q { max_scale * 0.5 + 1e-4 } else { 0.0 };
                    assert!(
                        (got - want).abs() <= tol,
                        "t={t} head={head} d={d}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn downshift_same_bits_trims_pregrown_capacity() {
        let mut c = LayerCache::new(geo(), 2, 2);
        c.ensure_q_cap(128);
        assert!(c.capacity_bytes() > 0);
        let freed = c.downshift_groups(2, 2);
        assert!(freed > 0);
        assert_eq!(c.q_capacity(), 0);
        assert_eq!(c.capacity_bytes(), 0);
        // and a no-op downshift reports zero without touching versions
        let v0 = c.version();
        assert_eq!(c.downshift_groups(2, 2), 0);
        assert_eq!(c.version(), v0);
    }

    #[test]
    #[should_panic(expected = "adds bits")]
    fn downshift_rejects_upshift() {
        let mut c = LayerCache::new(geo(), 2, 2);
        c.downshift_groups(4, 2);
    }

    #[test]
    fn restride_preserves_packed_contents() {
        // identical token stream into a paged cache vs one pre-grown to
        // full capacity: byte-identical packed state after growth
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(12) };
        let hd = 2 * 32;
        let toks: Vec<(Vec<f32>, Vec<f32>)> = (0..128).map(|_| tok(&mut g, hd)).collect();
        let mut paged = LayerCache::new(geo(), 1, 2);
        let mut grown = LayerCache::new(geo(), 1, 2);
        grown.ensure_q_cap(128);
        grown.ensure_res_cap(64);
        for (k, v) in &toks {
            paged.append_token(k, v);
            grown.append_token(k, v);
        }
        // capacities differ (64 vs pre-grown 128 tokens) but the cached
        // contents must be identical through every restride
        assert!(paged.q_capacity() < grown.q_capacity());
        assert_eq!(paged.n_q, grown.n_q);
        assert_eq!(paged.dequant_k_full(), grown.dequant_k_full());
        assert_eq!(paged.dequant_v_full(), grown.dequant_v_full());
    }

    // ---------------- shared base (copy-on-write prefix) ----------------

    #[test]
    fn attach_is_zero_copy() {
        let mut donor = LayerCache::new(geo(), 1, 1);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(41) };
        let hd = 2 * 32;
        let ks = g.vec_normal(70 * hd, 1.0);
        let vs = g.vec_normal(70 * hd, 1.0);
        donor.append_tokens(70, &ks, &vs);
        let base = Arc::new(donor.freeze_base());
        assert_eq!(base.n_tokens(), 70);
        assert!(base.bytes() > 0);
        let mut att = LayerCache::attach(base.clone());
        assert_eq!(att.n_tokens(), 70);
        assert_eq!(att.n_q, donor.n_q);
        assert_eq!(att.n_res(), donor.n_res());
        // attaching allocates nothing: the entire prefix is read through
        // the Arc; only post-divergence appends grow private pages
        assert_eq!(att.capacity_bytes(), 0);
        assert_eq!(att.used_bytes(), 0);
        for _ in 0..40 {
            let (k, v) = tok(&mut g, hd);
            att.append_token(&k, &v);
        }
        assert!(att.capacity_bytes() > 0);
        assert_eq!(att.n_tokens(), 110);
    }

    #[test]
    fn attached_matches_unshared_replay_prop() {
        check("base_attach_eq", 12, |g: &mut Gen| {
            let bits = *g.pick(&[0u8, 1, 2, 4]);
            let hd = 2 * 32;
            let n0 = g.usize_in(1, 90);
            let mut donor = LayerCache::new(geo(), bits, bits);
            let pk = g.vec_normal(n0 * hd, 1.0);
            let pv = g.vec_normal(n0 * hd, 1.0);
            donor.append_tokens(n0, &pk, &pv);
            let base = Arc::new(donor.freeze_base());
            let mut att = LayerCache::attach(base);
            if att.n_tokens() != donor.n_tokens() || att.n_res() != donor.n_res() {
                return Err("attach does not reproduce donor occupancy".into());
            }
            // replay an identical suffix into the donor (the unshared
            // baseline) and the attached borrower; growth prediction must
            // stay exact for the attached cache (pool gating depends on it)
            let n1 = g.usize_in(0, 192 - n0);
            for _ in 0..n1 {
                let (k, v) = tok(g, hd);
                let predicted = att.growth_bytes_for(1);
                let before = att.capacity_bytes();
                let fd = donor.append_token(&k, &v);
                let fa = att.append_token(&k, &v);
                if fd != fa {
                    return Err(format!("fold schedule diverges: {fd} vs {fa}"));
                }
                if att.capacity_bytes() - before != predicted {
                    return Err("growth prediction inexact for attached cache".into());
                }
            }
            // and a batched tail through the mixed ring+batch fold path
            let n2 = g.usize_in(0, 192 - n0 - n1);
            let ks = g.vec_normal(n2 * hd, 1.0);
            let vs = g.vec_normal(n2 * hd, 1.0);
            let predicted = att.growth_bytes_for(n2);
            let before = att.capacity_bytes();
            let fd = donor.append_tokens(n2, &ks, &vs);
            let fa = att.append_tokens(n2, &ks, &vs);
            if fd != fa {
                return Err(format!("batch fold schedule diverges: {fd} vs {fa}"));
            }
            if att.capacity_bytes() - before != predicted {
                return Err("batch growth prediction inexact".into());
            }
            if att.n_q != donor.n_q || att.n_res() != donor.n_res() {
                return Err("occupancy diverges after suffix".into());
            }
            // bit-identical reconstruction: folds are lossy, so this only
            // holds if the shared path reproduces the exact fold inputs
            if att.dequant_k_full() != donor.dequant_k_full()
                || att.dequant_v_full() != donor.dequant_v_full()
            {
                return Err("attached reconstruction diverges from unshared".into());
            }
            Ok(())
        });
    }

    #[test]
    fn refreeze_extended_base_chains() {
        // extend an attached cache past its base and freeze THAT: the new
        // node stitches base + private tail into one self-contained
        // snapshot (radix-style chaining), and a borrower of the chained
        // node reconstructs the full stream bit-identically
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(43) };
        let hd = 2 * 32;
        let mut root = LayerCache::new(geo(), 2, 1);
        let ks = g.vec_normal(50 * hd, 1.0);
        let vs = g.vec_normal(50 * hd, 1.0);
        root.append_tokens(50, &ks, &vs);
        let b0 = Arc::new(root.freeze_base());
        let mut mid = LayerCache::attach(b0);
        let ks2 = g.vec_normal(60 * hd, 1.0);
        let vs2 = g.vec_normal(60 * hd, 1.0);
        mid.append_tokens(60, &ks2, &vs2);
        root.append_tokens(60, &ks2, &vs2);
        let b1 = Arc::new(mid.freeze_base());
        assert_eq!(b1.n_tokens(), 110);
        let leaf = LayerCache::attach(b1);
        assert_eq!(leaf.dequant_k_full(), root.dequant_k_full());
        assert_eq!(leaf.dequant_v_full(), root.dequant_v_full());
    }

    #[test]
    #[should_panic(expected = "read-only base")]
    fn downshift_rejects_attached_cache() {
        let mut donor = LayerCache::new(geo(), 2, 2);
        let mut g = Gen { rng: crate::util::rng::SplitMix::new(44) };
        let hd = 2 * 32;
        let ks = g.vec_normal(40 * hd, 1.0);
        let vs = g.vec_normal(40 * hd, 1.0);
        donor.append_tokens(40, &ks, &vs);
        let mut att = LayerCache::attach(Arc::new(donor.freeze_base()));
        att.downshift_groups(1, 1);
    }
}
