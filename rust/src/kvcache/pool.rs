//! Cache pool: owns every sequence's per-layer caches, enforces a byte
//! budget, and tracks peak usage — the measurement substrate behind the
//! paper's Fig. 4 (peak GPU memory vs quantization configuration).
//!
//! Accounting is **demand-paged** (see `layer.rs`): a sequence is charged
//! only the pages its cache has actually allocated, so a short prompt costs
//! a few group pages instead of a full-context reservation and the
//! quantization win reaches the scheduler as real batch headroom. Charges
//! settle on every `with_seq`/`with_seqs` access (growth inside the closure
//! is metered by recomputing the resident footprint), which keeps the
//! invariant `in_use_bytes == Σ private capacity_bytes + Σ unique shared
//! bytes` — "pages charged == pages resident, shared pages charged once" —
//! at all times; a proptest drives random interleavings against it.
//!
//! **Shared prefixes** ([`SeqBase`]): a frozen all-layer snapshot is a
//! refcounted ledger entry charged to the budget exactly once no matter
//! how many sequences attach it ([`CachePool::allocate_attached`] /
//! [`CachePool::retain_shared`]); the last release frees its bytes exactly
//! once and wakes capacity waiters. Attached sequences allocate nothing
//! until they diverge — the first private page is the copy-on-write break,
//! counted in `cow_breaks`. Budget *gating* happens before mutation via
//! [`CachePool::reserve_growth`] (the engine calls it before every
//! prefill/decode append) and the scheduler's admission estimates
//! ([`CachePool::admit`] / [`CachePool::admit_growth`]); a failed
//! reservation surfaces as [`PoolError::BudgetExceeded`] *before* any
//! cache state changes, which is what lets the scheduler preempt instead
//! of panicking mid-decode.
//!
//! Every byte released (free, preemption, shrink) bumps a generation
//! counter and signals a condvar, so the scheduler blocks on
//! [`CachePool::wait_for_free`] instead of sleep-polling for capacity.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::layer::{CacheGeometry, LayerBase, LayerCache};
use crate::quant::QuantPolicy;

/// Immutable all-layer snapshot of a shared prefix: one refcounted
/// [`LayerBase`] per layer plus the absolute position it covers. Many
/// sequences attach one `SeqBase` read-only; the pool charges its bytes
/// ONCE per process regardless of how many sequences map it.
#[derive(Debug)]
pub struct SeqBase {
    /// Pool-ledger identity (layer 0's `LayerBase::id` — process-unique).
    pub id: u64,
    pub layers: Vec<Arc<LayerBase>>,
    /// Tokens covered (the position an attached sequence starts at).
    pub pos: usize,
}

impl SeqBase {
    /// Freeze `seq`'s full current state into a shareable snapshot.
    pub fn freeze(seq: &SeqCache) -> Self {
        assert!(!seq.layers.is_empty());
        let layers: Vec<_> =
            seq.layers.iter().map(|l| Arc::new(l.freeze_base())).collect();
        Self { id: layers[0].id, layers, pos: seq.pos }
    }

    /// Total snapshot bytes (what the pool charges once).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|b| b.bytes()).sum()
    }

    pub fn n_tokens(&self) -> usize {
        self.layers.first().map_or(0, |b| b.n_tokens())
    }

    /// Per-layer (k_bits, v_bits) — the policy fingerprint an attaching
    /// sequence must match exactly.
    pub fn bits_key(&self) -> Vec<(u8, u8)> {
        self.layers.iter().map(|b| (b.k_bits, b.v_bits)).collect()
    }
}

/// All layers of one sequence's KV cache.
#[derive(Debug, Clone)]
pub struct SeqCache {
    pub layers: Vec<LayerCache>,
    /// absolute position of the next token (tokens seen so far)
    pub pos: usize,
    /// Shared prefix this sequence is attached to (refcounted in the pool
    /// ledger while the sequence lives there).
    pub base: Option<Arc<SeqBase>>,
    /// Whether this sequence's copy-on-write break (first private page
    /// after attach) has been counted.
    pub cow_noted: bool,
}

impl SeqCache {
    pub fn new(geo: CacheGeometry, policy: &QuantPolicy) -> Self {
        let layers = (0..policy.n_layers())
            .map(|i| LayerCache::new(geo, policy.k_bits[i], policy.v_bits[i]))
            .collect();
        Self { layers, pos: 0, base: None, cow_noted: false }
    }

    /// Build a sequence mapping `base` read-only: zero bytes are copied
    /// and zero private pages allocated until the sequence diverges.
    pub fn attach(base: &Arc<SeqBase>) -> Self {
        let layers = base
            .layers
            .iter()
            .map(|b| LayerCache::attach(b.clone()))
            .collect();
        Self { layers, pos: base.pos, base: Some(base.clone()), cow_noted: false }
    }

    /// Rebuild a ROOT sequence (no base link) from frozen per-layer
    /// snapshots — the hibernation restore path. Every layer's packed
    /// region and residual ring is rematerialized at page-rounded
    /// capacities with fresh version stamps; the restored sequence's fold
    /// schedule (and therefore its decode output) is bit-identical to the
    /// donor's. See [`LayerCache::from_frozen`].
    pub fn from_frozen(layers: &[Arc<LayerBase>], pos: usize) -> Self {
        assert!(!layers.is_empty(), "from_frozen: empty snapshot");
        let layers =
            layers.iter().map(|b| LayerCache::from_frozen(b)).collect();
        Self { layers, pos, base: None, cow_noted: false }
    }

    pub fn used_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.used_bytes()).sum()
    }

    /// Resident PRIVATE allocation footprint (pages this sequence owns;
    /// an attached shared base is charged separately, once, by the pool).
    pub fn capacity_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.capacity_bytes()).sum()
    }

    /// Footprint when fully grown (the pre-paging static allocation).
    pub fn full_capacity_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.full_capacity_bytes()).sum()
    }

    /// Exact bytes of new pages appending `count` tokens will allocate.
    pub fn growth_bytes_for(&self, count: usize) -> usize {
        self.layers.iter().map(|l| l.growth_bytes_for(count)).sum()
    }
}

/// Why an allocation was refused (backpressure signal to the scheduler).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    BudgetExceeded { requested: usize, in_use: usize, budget: usize },
    UnknownSeq(u64),
    /// The sequence is pinned (a live session holds it) and cannot be freed.
    Pinned(u64),
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::BudgetExceeded { requested, in_use, budget } => write!(
                f,
                "cache budget exceeded: requested {requested}B, in use {in_use}B, budget {budget}B"
            ),
            PoolError::UnknownSeq(id) => write!(f, "unknown sequence {id}"),
            PoolError::Pinned(id) => {
                write!(f, "sequence {id} is pinned (unpin before freeing)")
            }
        }
    }
}
impl std::error::Error for PoolError {}

/// Thread-safe cache pool with demand-paged capacity accounting.
pub struct CachePool {
    geo: CacheGeometry,
    budget_bytes: usize,
    inner: Mutex<PoolInner>,
    /// Signalled on every capacity release (free / preempt / shrink) and by
    /// [`CachePool::notify_free`]; pairs with `inner`.
    free_cv: Condvar,
}

struct PoolInner {
    seqs: BTreeMap<u64, SeqCache>,
    /// Sequences that refuse `free` until unpinned (session retention).
    pinned: BTreeSet<u64>,
    next_id: u64,
    /// Σ capacity_bytes over live sequences (resident pages).
    in_use: usize,
    /// True peak of resident bytes.
    peak: usize,
    total_allocs: u64,
    total_frees: u64,
    /// Page-grant events (initial allocations + every growth settle).
    page_allocs: u64,
    /// Cumulative bytes granted as pages.
    page_alloc_bytes: u64,
    /// Cumulative bytes released (frees, preemptions, shrinks).
    page_free_bytes: u64,
    /// Bumped on every release and by `notify_free`; lets a waiter detect
    /// frees that happened between observing the pool and blocking.
    free_epoch: u64,
    /// Shared-segment ledger: base id → (refcount, bytes). Bytes enter
    /// `in_use` exactly once on the 0→1 retain and leave exactly once on
    /// the →0 release, independent of how many sequences map the base.
    shared: BTreeMap<u64, (usize, usize)>,
    /// Σ unique shared bytes currently charged (subset of `in_use`).
    shared_bytes: usize,
    /// Cumulative bytes NOT charged because a retain found the base
    /// already resident (the density win of sharing).
    shared_bytes_saved: u64,
    /// Copy-on-write breaks: attached sequences that allocated their
    /// first private page (diverged from the shared prefix).
    cow_breaks: u64,
}

impl PoolInner {
    /// Meter a capacity change observed across a `with_seq*` closure.
    /// Returns true when capacity was released (waiters should be woken).
    fn settle(&mut self, before: usize, after: usize) -> bool {
        if after > before {
            let d = after - before;
            self.in_use += d;
            self.peak = self.peak.max(self.in_use);
            self.page_allocs += 1;
            self.page_alloc_bytes += d as u64;
            false
        } else if after < before {
            let d = before - after;
            self.in_use -= d;
            self.page_free_bytes += d as u64;
            self.free_epoch += 1;
            true
        } else {
            false
        }
    }

    /// Take one reference on shared base `id` (`bytes` = its charge).
    /// The 0→1 transition is budget-gated and charges `in_use`.
    fn retain_shared(
        &mut self,
        id: u64,
        bytes: usize,
        budget: usize,
    ) -> Result<(), PoolError> {
        match self.shared.get_mut(&id) {
            Some(e) => {
                e.0 += 1;
                self.shared_bytes_saved += bytes as u64;
            }
            None => {
                if self.in_use + bytes > budget {
                    return Err(PoolError::BudgetExceeded {
                        requested: bytes,
                        in_use: self.in_use,
                        budget,
                    });
                }
                self.in_use += bytes;
                self.peak = self.peak.max(self.in_use);
                self.shared_bytes += bytes;
                if bytes > 0 {
                    self.page_allocs += 1;
                    self.page_alloc_bytes += bytes as u64;
                }
                self.shared.insert(id, (1, bytes));
            }
        }
        Ok(())
    }

    /// Drop one reference on shared base `id`; on →0 the entry's bytes are
    /// released exactly once. Returns the bytes released (0 while other
    /// references remain).
    fn release_shared(&mut self, id: u64) -> usize {
        let e = self.shared.get_mut(&id).expect("release of unknown shared base");
        e.0 -= 1;
        if e.0 > 0 {
            return 0;
        }
        let bytes = e.1;
        self.shared.remove(&id);
        self.in_use -= bytes;
        self.shared_bytes -= bytes;
        if bytes > 0 {
            self.page_free_bytes += bytes as u64;
            self.free_epoch += 1;
        }
        bytes
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolStats {
    pub n_seqs: usize,
    pub pinned_seqs: usize,
    /// Resident page bytes (== Σ per-sequence `capacity_bytes`).
    pub in_use_bytes: usize,
    pub used_bytes: usize,
    /// True peak of resident bytes (bytes actually allocated, not
    /// worst-case reservations).
    pub peak_bytes: usize,
    pub budget_bytes: usize,
    pub total_allocs: u64,
    pub total_frees: u64,
    /// Page-grant events (allocations + growths).
    pub page_allocs: u64,
    /// Cumulative bytes granted as pages.
    pub page_alloc_bytes: u64,
    /// Cumulative bytes released.
    pub page_free_bytes: u64,
    /// Live shared prefix segments (unique bases in the ledger).
    pub shared_segs: usize,
    /// Unique shared bytes currently charged (subset of `in_use_bytes`).
    pub shared_bytes: usize,
    /// Cumulative bytes avoided by attaching already-resident bases.
    pub shared_bytes_saved: u64,
    /// Attached sequences that diverged (allocated a first private page).
    pub cow_breaks: u64,
}

impl CachePool {
    pub fn new(geo: CacheGeometry, budget_bytes: usize) -> Self {
        Self {
            geo,
            budget_bytes,
            inner: Mutex::new(PoolInner {
                seqs: BTreeMap::new(),
                pinned: BTreeSet::new(),
                next_id: 1,
                in_use: 0,
                peak: 0,
                total_allocs: 0,
                total_frees: 0,
                page_allocs: 0,
                page_alloc_bytes: 0,
                page_free_bytes: 0,
                free_epoch: 0,
                shared: BTreeMap::new(),
                shared_bytes: 0,
                shared_bytes_saved: 0,
                cow_breaks: 0,
            }),
            free_cv: Condvar::new(),
        }
    }

    pub fn geometry(&self) -> CacheGeometry {
        self.geo
    }

    /// Allocate a cache for a new sequence under `policy`. Charges only the
    /// initial (near-empty) footprint — pages are charged as the sequence
    /// grows; use [`CachePool::admit`] to gate on the projected footprint.
    pub fn allocate(&self, policy: &QuantPolicy) -> Result<u64, PoolError> {
        let cache = SeqCache::new(self.geo, policy);
        let cap = cache.capacity_bytes();
        let mut inner = self.inner.lock().unwrap();
        if inner.in_use + cap > self.budget_bytes {
            return Err(PoolError::BudgetExceeded {
                requested: cap,
                in_use: inner.in_use,
                budget: self.budget_bytes,
            });
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.in_use += cap;
        inner.peak = inner.peak.max(inner.in_use);
        inner.total_allocs += 1;
        if cap > 0 {
            inner.page_allocs += 1;
            inner.page_alloc_bytes += cap as u64;
        }
        inner.seqs.insert(id, cache);
        Ok(id)
    }

    /// Admit an externally built ROOT sequence into the pool (the
    /// hibernation restore path: a [`SeqCache::from_frozen`] rebuild).
    /// Budget-gated on the sequence's already-materialized resident
    /// footprint exactly like [`CachePool::allocate`]; on refusal the
    /// cache is handed back so the caller can retry after a
    /// [`CachePool::wait_for_free`].
    pub fn adopt(&self, cache: SeqCache) -> Result<u64, (SeqCache, PoolError)> {
        assert!(cache.base.is_none(), "adopt: only root sequences");
        let cap = cache.capacity_bytes();
        let mut inner = self.inner.lock().unwrap();
        if inner.in_use + cap > self.budget_bytes {
            let err = PoolError::BudgetExceeded {
                requested: cap,
                in_use: inner.in_use,
                budget: self.budget_bytes,
            };
            return Err((cache, err));
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.in_use += cap;
        inner.peak = inner.peak.max(inner.in_use);
        inner.total_allocs += 1;
        if cap > 0 {
            inner.page_allocs += 1;
            inner.page_alloc_bytes += cap as u64;
        }
        inner.seqs.insert(id, cache);
        Ok(id)
    }

    /// Allocate a sequence ATTACHED to a shared base: the base takes one
    /// ledger reference (charged once, on its first retain anywhere) and
    /// the sequence itself starts with zero private pages — it is charged
    /// only as it diverges (copy-on-write).
    pub fn allocate_attached(&self, base: &Arc<SeqBase>) -> Result<u64, PoolError> {
        let cache = SeqCache::attach(base);
        let cap = cache.capacity_bytes();
        debug_assert_eq!(cap, 0, "attach must allocate no private pages");
        let mut inner = self.inner.lock().unwrap();
        inner.retain_shared(base.id, base.bytes(), self.budget_bytes)?;
        let id = inner.next_id;
        inner.next_id += 1;
        inner.in_use += cap;
        inner.total_allocs += 1;
        inner.seqs.insert(id, cache);
        Ok(id)
    }

    /// Re-point an EXISTING sequence at a shared base (the prefix-cache
    /// restore path): its private pages are released, its previous base
    /// reference (if any) dropped, and one reference taken on `base` — all
    /// atomically, gated on the NET budget change (a non-resident base is
    /// charged, minus the pages this restore frees).
    pub fn attach_base(&self, id: u64, base: &Arc<SeqBase>) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        let cache = inner.seqs.get(&id).ok_or(PoolError::UnknownSeq(id))?;
        let cap = cache.capacity_bytes();
        let old_base = cache.base.clone();
        if !inner.shared.contains_key(&base.id)
            && inner.in_use + base.bytes() > self.budget_bytes + cap
        {
            return Err(PoolError::BudgetExceeded {
                requested: base.bytes().saturating_sub(cap),
                in_use: inner.in_use,
                budget: self.budget_bytes,
            });
        }
        inner
            .retain_shared(base.id, base.bytes(), usize::MAX)
            .expect("gated above");
        inner.seqs.insert(id, SeqCache::attach(base));
        inner.in_use -= cap;
        let mut released = cap;
        if cap > 0 {
            inner.page_free_bytes += cap as u64;
        }
        if let Some(ob) = old_base {
            released += inner.release_shared(ob.id);
        }
        if released > 0 {
            inner.free_epoch += 1;
            drop(inner);
            self.free_cv.notify_all();
        }
        Ok(())
    }

    /// Freeze a live sequence's full state into a shared base and re-point
    /// the sequence at it: its private pages convert into the (compacted)
    /// shared charge, its logical state is unchanged, and the returned base
    /// can be attached by any number of new sequences. An undiverged
    /// attached sequence short-circuits to its existing base (no copy).
    pub fn share_seq(&self, id: u64) -> Result<Arc<SeqBase>, PoolError> {
        let mut inner = self.inner.lock().unwrap();
        let cache = inner.seqs.get(&id).ok_or(PoolError::UnknownSeq(id))?;
        if cache.capacity_bytes() == 0 {
            if let Some(b) = cache.base.clone() {
                return Ok(b);
            }
        }
        let base = Arc::new(SeqBase::freeze(cache));
        let bb = base.bytes();
        let cap = cache.capacity_bytes();
        let old_base = cache.base.clone();
        // net gate: the private pages convert into the shared charge
        if inner.in_use + bb > self.budget_bytes + cap {
            return Err(PoolError::BudgetExceeded {
                requested: bb.saturating_sub(cap),
                in_use: inner.in_use,
                budget: self.budget_bytes,
            });
        }
        inner
            .retain_shared(base.id, bb, usize::MAX)
            .expect("gated above");
        inner.seqs.insert(id, SeqCache::attach(&base));
        inner.in_use -= cap;
        let mut released = cap;
        if cap > 0 {
            inner.page_free_bytes += cap as u64;
        }
        if let Some(ob) = old_base {
            released += inner.release_shared(ob.id);
        }
        if released > 0 {
            inner.free_epoch += 1;
            drop(inner);
            self.free_cv.notify_all();
        }
        Ok(base)
    }

    /// Take a standalone reference on a shared base (a registered/pinned
    /// prefix holds one so its pages survive with no sequences attached).
    /// The first retain anywhere is budget-gated and charges the pool.
    pub fn retain_shared(&self, base: &Arc<SeqBase>) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        inner.retain_shared(base.id, base.bytes(), self.budget_bytes)
    }

    /// Drop a standalone shared-base reference. The last release (counting
    /// attached sequences) frees the base's bytes exactly once and wakes
    /// capacity waiters.
    pub fn release_shared(&self, base_id: u64) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.shared.contains_key(&base_id) {
            return Err(PoolError::UnknownSeq(base_id));
        }
        let released = inner.release_shared(base_id);
        if released > 0 {
            drop(inner);
            self.free_cv.notify_all();
        }
        Ok(())
    }

    /// Current ledger refcount of a shared base (0 = not resident).
    pub fn shared_refs(&self, base_id: u64) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.shared.get(&base_id).map_or(0, |e| e.0)
    }

    /// Free a sequence's cache. Pinned sequences are refused — unpin first.
    /// An attached sequence drops its shared-base reference (the base's
    /// bytes are freed only when the LAST reference goes). Wakes capacity
    /// waiters.
    pub fn free(&self, id: u64) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.seqs.contains_key(&id) {
            return Err(PoolError::UnknownSeq(id));
        }
        if inner.pinned.contains(&id) {
            return Err(PoolError::Pinned(id));
        }
        let cache = inner.seqs.remove(&id).unwrap();
        let cap = cache.capacity_bytes();
        inner.in_use -= cap;
        inner.page_free_bytes += cap as u64;
        inner.total_frees += 1;
        let mut released = cap;
        if let Some(base) = cache.base.as_ref() {
            released += inner.release_shared(base.id);
        }
        // only a real byte release advances the epoch — freeing an empty
        // cache changes nothing a capacity waiter could use
        if released > 0 {
            inner.free_epoch += 1;
            drop(inner);
            self.free_cv.notify_all();
        }
        Ok(())
    }

    /// Pin a sequence: `free` will refuse it until `unpin`. Guards session
    /// caches against the scheduler's per-request release paths.
    pub fn pin(&self, id: u64) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.seqs.contains_key(&id) {
            return Err(PoolError::UnknownSeq(id));
        }
        inner.pinned.insert(id);
        Ok(())
    }

    pub fn unpin(&self, id: u64) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.seqs.contains_key(&id) {
            return Err(PoolError::UnknownSeq(id));
        }
        inner.pinned.remove(&id);
        Ok(())
    }

    /// Run `f` with mutable access to one sequence's cache. Page growth (or
    /// shrink) performed inside `f` is settled into the pool accounting.
    pub fn with_seq<R>(
        &self,
        id: u64,
        f: impl FnOnce(&mut SeqCache) -> R,
    ) -> Result<R, PoolError> {
        let mut inner = self.inner.lock().unwrap();
        let (r, before, after, cow) = {
            let cache = inner.seqs.get_mut(&id).ok_or(PoolError::UnknownSeq(id))?;
            let before = cache.capacity_bytes();
            let r = f(cache);
            let after = cache.capacity_bytes();
            let cow = cache.base.is_some() && !cache.cow_noted && after > 0;
            if cow {
                cache.cow_noted = true;
            }
            (r, before, after, cow)
        };
        if cow {
            inner.cow_breaks += 1;
        }
        let released = inner.settle(before, after);
        drop(inner);
        if released {
            self.free_cv.notify_all();
        }
        Ok(r)
    }

    /// Run `f` with mutable access to several sequences at once (batch
    /// assembly). IDs must be distinct.
    pub fn with_seqs<R>(
        &self,
        ids: &[u64],
        f: impl FnOnce(&mut [&mut SeqCache]) -> R,
    ) -> Result<R, PoolError> {
        let mut inner = self.inner.lock().unwrap();
        // split the map into disjoint mutable borrows
        let inner_ref = &mut *inner;
        let mut refs: Vec<*mut SeqCache> = Vec::with_capacity(ids.len());
        for &id in ids {
            let c = inner_ref.seqs.get_mut(&id).ok_or(PoolError::UnknownSeq(id))?;
            let p = c as *mut SeqCache;
            if refs.contains(&p) {
                panic!("duplicate sequence id {id} in batch");
            }
            refs.push(p);
        }
        // SAFETY: all pointers come from distinct keys of the same map and
        // the map is locked for the duration of `f`.
        let mut borrows: Vec<&mut SeqCache> =
            refs.into_iter().map(|p| unsafe { &mut *p }).collect();
        let before: usize = borrows.iter().map(|c| c.capacity_bytes()).sum();
        let r = f(&mut borrows);
        let after: usize = borrows.iter().map(|c| c.capacity_bytes()).sum();
        let mut cows = 0u64;
        for c in borrows.iter_mut() {
            if c.base.is_some() && !c.cow_noted && c.capacity_bytes() > 0 {
                c.cow_noted = true;
                cows += 1;
            }
        }
        drop(borrows);
        inner_ref.cow_breaks += cows;
        let released = inner_ref.settle(before, after);
        drop(inner);
        if released {
            self.free_cv.notify_all();
        }
        Ok(r)
    }

    /// Run `f` with SHARED access to several sequences at once (batch
    /// gather / the pipelined prefetch worker). Unlike
    /// [`CachePool::with_seqs`] this neither requires exclusive access nor
    /// settles capacity (nothing can mutate), and duplicate ids are
    /// permitted. Small batches borrow through a stack-inline pointer
    /// array, so the steady-state decode gather path stays allocation-free.
    pub fn with_seqs_ref<R>(
        &self,
        ids: &[u64],
        f: impl FnOnce(&[&SeqCache]) -> R,
    ) -> Result<R, PoolError> {
        const INLINE: usize = 16;
        let inner = self.inner.lock().unwrap();
        if ids.len() <= INLINE {
            let mut arr: [std::mem::MaybeUninit<&SeqCache>; INLINE] =
                [const { std::mem::MaybeUninit::uninit() }; INLINE];
            for (i, &id) in ids.iter().enumerate() {
                arr[i].write(
                    inner.seqs.get(&id).ok_or(PoolError::UnknownSeq(id))?,
                );
            }
            // SAFETY: the first ids.len() elements were just initialized.
            let refs: &[&SeqCache] = unsafe {
                std::slice::from_raw_parts(
                    arr.as_ptr() as *const &SeqCache,
                    ids.len(),
                )
            };
            Ok(f(refs))
        } else {
            let mut refs: Vec<&SeqCache> = Vec::with_capacity(ids.len());
            for &id in ids {
                refs.push(inner.seqs.get(&id).ok_or(PoolError::UnknownSeq(id))?);
            }
            Ok(f(&refs))
        }
    }

    // -----------------------------------------------------------------
    // budget gating (checks BEFORE mutation — the preemption trigger)
    // -----------------------------------------------------------------

    /// Check that appending `counts[i]` tokens to `ids[i]` fits the budget.
    /// Pure gate: allocates nothing; the growth itself happens (and is
    /// settled) inside the subsequent `with_seqs` appends. Exact because
    /// paged growth is deterministic (`LayerCache::growth_bytes_for`).
    pub fn reserve_growth(&self, ids: &[u64], counts: &[usize]) -> Result<(), PoolError> {
        assert_eq!(ids.len(), counts.len());
        let inner = self.inner.lock().unwrap();
        let mut needed = 0usize;
        for (&id, &count) in ids.iter().zip(counts) {
            let seq = inner.seqs.get(&id).ok_or(PoolError::UnknownSeq(id))?;
            needed += seq.growth_bytes_for(count);
        }
        if inner.in_use + needed > self.budget_bytes {
            return Err(PoolError::BudgetExceeded {
                requested: needed,
                in_use: inner.in_use,
                budget: self.budget_bytes,
            });
        }
        Ok(())
    }

    /// Resident bytes a fresh sequence under `policy` will have allocated
    /// once it holds `n_tokens` tokens (page-rounded, per layer).
    pub fn estimate_bytes(&self, policy: &QuantPolicy, n_tokens: usize) -> usize {
        let c = SeqCache::new(self.geo, policy); // allocates nothing (paged)
        c.capacity_bytes() + c.growth_bytes_for(n_tokens)
    }

    /// Expected-pages admission gate for a NEW sequence: would a fresh
    /// cache grown to `n_tokens` fit next to the current residents?
    /// Advisory — growth is re-gated at every append, and the scheduler
    /// preempts when optimistically admitted sequences later collide.
    pub fn admit(&self, policy: &QuantPolicy, n_tokens: usize) -> Result<(), PoolError> {
        let est = self.estimate_bytes(policy, n_tokens);
        let inner = self.inner.lock().unwrap();
        if inner.in_use + est > self.budget_bytes {
            return Err(PoolError::BudgetExceeded {
                requested: est,
                in_use: inner.in_use,
                budget: self.budget_bytes,
            });
        }
        Ok(())
    }

    /// Admission gate for growing an EXISTING (e.g. session) sequence by
    /// `count` tokens.
    pub fn admit_growth(&self, id: u64, count: usize) -> Result<(), PoolError> {
        self.reserve_growth(&[id], &[count])
    }

    /// Admission gate for a sequence that will ATTACH `base` and then grow
    /// by `new_tokens` private tokens: the projected footprint is NET of
    /// the shared pages — only the private tail, plus the base's bytes
    /// when (and only when) the base is not already resident.
    pub fn admit_attached(
        &self,
        base: &Arc<SeqBase>,
        new_tokens: usize,
    ) -> Result<(), PoolError> {
        let probe = SeqCache::attach(base); // copies nothing (Arc views)
        let grow = probe.growth_bytes_for(new_tokens);
        let inner = self.inner.lock().unwrap();
        let base_charge = if inner.shared.contains_key(&base.id) {
            0
        } else {
            base.bytes()
        };
        if inner.in_use + base_charge + grow > self.budget_bytes {
            return Err(PoolError::BudgetExceeded {
                requested: base_charge + grow,
                in_use: inner.in_use,
                budget: self.budget_bytes,
            });
        }
        Ok(())
    }

    /// Whether `bytes` additional resident bytes fit the budget right now
    /// (prefix-cache restore gate).
    pub fn has_headroom(&self, bytes: usize) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.in_use + bytes <= self.budget_bytes
    }

    // -----------------------------------------------------------------
    // capacity waiting (replaces scheduler sleep-polling)
    // -----------------------------------------------------------------

    /// Current free-generation counter. Capture it BEFORE an admission
    /// attempt; a release between the bounce and [`CachePool::wait_for_free`]
    /// then returns immediately instead of being lost.
    pub fn free_epoch(&self) -> u64 {
        self.inner.lock().unwrap().free_epoch
    }

    /// Block until capacity is released after `seen_epoch` (or `timeout`, a
    /// backstop — every release path and `notify_free` signal the condvar,
    /// so waiters do not poll).
    pub fn wait_for_free(&self, seen_epoch: u64, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        while inner.free_epoch == seen_epoch {
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            let (guard, _) = self
                .free_cv
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
        }
    }

    /// Wake capacity waiters without freeing anything (shutdown path).
    pub fn notify_free(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.free_epoch += 1;
        drop(inner);
        self.free_cv.notify_all();
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        PoolStats {
            n_seqs: inner.seqs.len(),
            pinned_seqs: inner.pinned.len(),
            in_use_bytes: inner.in_use,
            used_bytes: inner.seqs.values().map(|c| c.used_bytes()).sum(),
            peak_bytes: inner.peak,
            budget_bytes: self.budget_bytes,
            total_allocs: inner.total_allocs,
            total_frees: inner.total_frees,
            page_allocs: inner.page_allocs,
            page_alloc_bytes: inner.page_alloc_bytes,
            page_free_bytes: inner.page_free_bytes,
            shared_segs: inner.shared.len(),
            shared_bytes: inner.shared_bytes,
            shared_bytes_saved: inner.shared_bytes_saved,
            cow_breaks: inner.cow_breaks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> CacheGeometry {
        CacheGeometry { n_heads: 2, max_ctx: 128, d_head: 32, group: 32, residual: 64 }
    }

    fn append_n(pool: &CachePool, id: u64, n: usize) {
        let hd = 2 * 32;
        pool.with_seq(id, |s| {
            for layer in &mut s.layers {
                for _ in 0..n {
                    layer.append_token(&vec![1.0; hd], &vec![1.0; hd]);
                }
            }
            s.pos += n;
        })
        .unwrap();
    }

    #[test]
    fn alloc_free_accounting() {
        let pool = CachePool::new(geo(), usize::MAX);
        let p = QuantPolicy::kivi(2, 2);
        let a = pool.allocate(&p).unwrap();
        let b = pool.allocate(&p).unwrap();
        // paged: fresh sequences are charged (near) nothing
        let s0 = pool.stats();
        assert_eq!(s0.n_seqs, 2);
        assert_eq!(s0.in_use_bytes, 0, "fresh quantized caches hold no pages");
        // growth charges pages; both sequences grow identically
        append_n(&pool, a, 40);
        append_n(&pool, b, 40);
        let s = pool.stats();
        assert!(s.in_use_bytes > 0);
        assert_eq!(s.in_use_bytes, s.peak_bytes);
        assert!(s.page_allocs >= 2);
        assert_eq!(s.page_alloc_bytes - s.page_free_bytes, s.in_use_bytes as u64);
        pool.free(a).unwrap();
        let s2 = pool.stats();
        assert_eq!(s2.n_seqs, 1);
        assert_eq!(s2.in_use_bytes, s.in_use_bytes / 2);
        assert_eq!(s2.peak_bytes, s.peak_bytes); // peak sticks
        pool.free(b).unwrap();
        assert_eq!(pool.stats().in_use_bytes, 0);
        assert!(pool.free(b).is_err());
    }

    #[test]
    fn admission_estimate_backpressure() {
        let p = QuantPolicy::kivi(2, 2);
        let probe = CachePool::new(geo(), usize::MAX);
        let full = probe.estimate_bytes(&p, 128 + 63);
        assert!(full > 0);
        // budget for ~2 fully grown sequences
        let pool = CachePool::new(geo(), full * 2 + 1);
        let a = pool.allocate(&p).unwrap();
        let b = pool.allocate(&p).unwrap();
        assert!(pool.admit(&p, 128 + 63).is_ok(), "nothing resident yet");
        append_n(&pool, a, 128 + 63);
        append_n(&pool, b, 128 + 63);
        match pool.admit(&p, 128 + 63) {
            Err(PoolError::BudgetExceeded { requested, budget, .. }) => {
                assert!(requested <= budget, "transient: waiting will free capacity");
            }
            other => panic!("expected backpressure, got {other:?}"),
        }
        // a short sequence still fits in the remaining slack? No — the two
        // residents consumed the budget; growth reservation must refuse too.
        let c = pool.allocate(&p).unwrap();
        assert!(pool.reserve_growth(&[c], &[64]).is_err());
        pool.free(a).unwrap();
        assert!(pool.admit(&p, 64).is_ok());
    }

    #[test]
    fn reserve_growth_is_exact_gate() {
        let p = QuantPolicy::kivi(2, 2);
        let probe = CachePool::new(geo(), usize::MAX);
        let need_40 = {
            let id = probe.allocate(&p).unwrap();
            let b = probe
                .with_seq(id, |s| s.growth_bytes_for(40))
                .unwrap();
            probe.free(id).unwrap();
            b
        };
        let pool = CachePool::new(geo(), need_40);
        let id = pool.allocate(&p).unwrap();
        assert!(pool.reserve_growth(&[id], &[40]).is_ok());
        append_n(&pool, id, 40);
        assert_eq!(pool.stats().in_use_bytes, need_40, "charge == reservation");
        // one more page cannot fit
        assert!(pool.reserve_growth(&[id], &[64]).is_err());
    }

    #[test]
    fn policy_changes_capacity() {
        // paged: FRESH caches all cost ~nothing; the projected footprints
        // (and the grown footprints) must still order by bits
        let pool = CachePool::new(geo(), usize::MAX);
        let n = 128 + 63;
        let est_f = pool.estimate_bytes(&QuantPolicy::float32(4), n);
        let est_1 = pool.estimate_bytes(&QuantPolicy::kivi(4, 1), n);
        // capacity includes the fixed fp32 residual window (R=64 vs
        // T=128 here), so the full 16x data ratio is diluted at this
        // tiny geometry; at the bench geometry (T>>R) the gap widens.
        assert!(est_1 < est_f / 2, "1-bit cache should be well below fp32");
        let id_f = pool.allocate(&QuantPolicy::float32(4)).unwrap();
        let id_1 = pool.allocate(&QuantPolicy::kivi(4, 1)).unwrap();
        append_n(&pool, id_f, n);
        append_n(&pool, id_1, n);
        let cap_f = pool.with_seq(id_f, |c| c.capacity_bytes()).unwrap();
        let cap_1 = pool.with_seq(id_1, |c| c.capacity_bytes()).unwrap();
        assert!(cap_1 < cap_f / 2);
        assert_eq!(cap_f, est_f, "estimate matches grown footprint");
        assert_eq!(cap_1, est_1);
    }

    #[test]
    fn pinned_seq_refuses_free_until_unpinned() {
        let pool = CachePool::new(geo(), usize::MAX);
        let p = QuantPolicy::kivi(2, 2);
        let id = pool.allocate(&p).unwrap();
        pool.pin(id).unwrap();
        assert_eq!(pool.stats().pinned_seqs, 1);
        match pool.free(id) {
            Err(PoolError::Pinned(got)) => assert_eq!(got, id),
            other => panic!("expected Pinned, got {other:?}"),
        }
        // still allocated and accessible
        assert_eq!(pool.stats().n_seqs, 1);
        pool.with_seq(id, |c| c.pos).unwrap();
        pool.unpin(id).unwrap();
        assert_eq!(pool.stats().pinned_seqs, 0);
        pool.free(id).unwrap();
        assert_eq!(pool.stats().n_seqs, 0);
        assert!(pool.pin(id).is_err(), "pin of freed seq must fail");
    }

    #[test]
    fn with_seqs_disjoint_access() {
        let pool = CachePool::new(geo(), usize::MAX);
        let p = QuantPolicy::float32(1);
        let a = pool.allocate(&p).unwrap();
        let b = pool.allocate(&p).unwrap();
        let hd = 2 * 32;
        pool.with_seqs(&[a, b], |seqs| {
            seqs[0].layers[0].append_token(&vec![1.0; hd], &vec![1.0; hd]);
            seqs[1].layers[0].append_token(&vec![2.0; hd], &vec![2.0; hd]);
        })
        .unwrap();
        assert_eq!(pool.with_seq(a, |c| c.layers[0].n_res()).unwrap(), 1);
        assert!(pool.with_seqs(&[a, 999], |_| ()).is_err());
    }

    #[test]
    fn with_seqs_ref_shared_access() {
        let pool = CachePool::new(geo(), usize::MAX);
        let p = QuantPolicy::float32(1);
        let a = pool.allocate(&p).unwrap();
        let b = pool.allocate(&p).unwrap();
        let hd = 2 * 32;
        pool.with_seq(a, |s| {
            s.layers[0].append_token(&vec![3.0; hd], &vec![3.0; hd]);
        })
        .unwrap();
        let (na, nb) = pool
            .with_seqs_ref(&[a, b], |seqs| {
                (seqs[0].layers[0].n_res(), seqs[1].layers[0].n_res())
            })
            .unwrap();
        assert_eq!((na, nb), (1, 0));
        // duplicate ids are fine on the shared path (read-only)
        let n = pool
            .with_seqs_ref(&[a, a], |seqs| {
                seqs[0].layers[0].n_res() + seqs[1].layers[0].n_res()
            })
            .unwrap();
        assert_eq!(n, 2);
        assert!(pool.with_seqs_ref(&[a, 999], |_| ()).is_err());
        // > inline capacity falls back to the heap path
        let many: Vec<u64> =
            (0..20).map(|_| pool.allocate(&p).unwrap()).collect();
        let count = pool.with_seqs_ref(&many, |seqs| seqs.len()).unwrap();
        assert_eq!(count, 20);
    }

    #[test]
    fn free_bumps_epoch_and_wakes_waiter() {
        let pool = std::sync::Arc::new(CachePool::new(geo(), usize::MAX));
        let p = QuantPolicy::kivi(2, 2);
        let id = pool.allocate(&p).unwrap();
        append_n(&pool, id, 10); // resident pages: the free releases bytes
        let epoch = pool.free_epoch();
        let waiter = {
            let pool = pool.clone();
            std::thread::spawn(move || {
                let t0 = Instant::now();
                pool.wait_for_free(epoch, Duration::from_secs(5));
                t0.elapsed()
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        pool.free(id).unwrap();
        let waited = waiter.join().unwrap();
        assert!(waited < Duration::from_secs(4), "woken by the free, not the backstop");
        assert!(pool.free_epoch() > epoch);
        // a release that already happened is seen without blocking
        let t0 = Instant::now();
        pool.wait_for_free(epoch, Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn pages_charged_equals_pages_resident_prop() {
        // random interleavings of allocate / append (growth) / fold /
        // free (≈ preemption): the pool's charge must equal the summed
        // resident footprint after EVERY operation, and the cumulative
        // page ledger must reconcile.
        use crate::util::prop::{check, Gen};
        check("pool_paged_invariant", 15, |g: &mut Gen| {
            let pool = CachePool::new(geo(), usize::MAX);
            let policies =
                [QuantPolicy::kivi(2, 1), QuantPolicy::kivi(2, 2), QuantPolicy::float32(2)];
            let mut live: Vec<u64> = Vec::new();
            let hd = 2 * 32;
            for _ in 0..g.usize_in(5, 25) {
                match g.usize_in(0, 3) {
                    0 => {
                        let p = g.pick(&policies).clone();
                        live.push(pool.allocate(&p).unwrap());
                    }
                    1 | 2 if !live.is_empty() => {
                        // grow a random live sequence by a random stretch
                        // (driving appends AND folds past R)
                        let id = *g.pick(&live);
                        let count = g.usize_in(1, 50);
                        let fits = pool
                            .with_seq(id, |s| {
                                s.pos + count <= 128 + 64
                            })
                            .unwrap();
                        if !fits {
                            continue;
                        }
                        if pool.reserve_growth(&[id], &[count]).is_err() {
                            continue;
                        }
                        pool.with_seq(id, |s| {
                            for layer in &mut s.layers {
                                for _ in 0..count {
                                    layer.append_token(&vec![1.0; hd], &vec![1.0; hd]);
                                }
                            }
                            s.pos += count;
                        })
                        .unwrap();
                    }
                    _ if !live.is_empty() => {
                        // preemption-style release of a random victim
                        let i = g.usize_in(0, live.len() - 1);
                        let id = live.swap_remove(i);
                        pool.free(id).unwrap();
                    }
                    _ => {}
                }
                let s = pool.stats();
                let resident: usize = live
                    .iter()
                    .map(|&id| pool.with_seq(id, |c| c.capacity_bytes()).unwrap())
                    .sum();
                if s.in_use_bytes != resident {
                    return Err(format!(
                        "charged {} != resident {resident}",
                        s.in_use_bytes
                    ));
                }
                if s.page_alloc_bytes - s.page_free_bytes != s.in_use_bytes as u64 {
                    return Err(format!(
                        "page ledger off: +{} -{} vs in_use {}",
                        s.page_alloc_bytes, s.page_free_bytes, s.in_use_bytes
                    ));
                }
                if s.peak_bytes < s.in_use_bytes {
                    return Err("peak below in_use".into());
                }
            }
            Ok(())
        });
    }

    /// Build a frozen shared base with `n` tokens under `p`.
    fn mk_base(p: &QuantPolicy, n: usize) -> Arc<SeqBase> {
        let mut donor = SeqCache::new(geo(), p);
        let hd = 2 * 32;
        for layer in &mut donor.layers {
            for _ in 0..n {
                layer.append_token(&vec![1.0; hd], &vec![1.0; hd]);
            }
        }
        donor.pos = n;
        Arc::new(SeqBase::freeze(&donor))
    }

    #[test]
    fn shared_base_charged_once_and_freed_once() {
        let pool = CachePool::new(geo(), usize::MAX);
        let p = QuantPolicy::kivi(2, 1);
        let base = mk_base(&p, 70);
        let bb = base.bytes();
        assert!(bb > 0);
        // three borrowers: the base is charged exactly once
        let a = pool.allocate_attached(&base).unwrap();
        let b = pool.allocate_attached(&base).unwrap();
        let c = pool.allocate_attached(&base).unwrap();
        let s = pool.stats();
        assert_eq!(s.in_use_bytes, bb, "3 borrowers, one charge");
        assert_eq!(s.shared_segs, 1);
        assert_eq!(s.shared_bytes, bb);
        assert_eq!(s.shared_bytes_saved, 2 * bb as u64, "2nd+3rd retains saved");
        assert_eq!(pool.shared_refs(base.id), 3);
        assert_eq!(s.cow_breaks, 0);
        // divergence: borrower `a` grows a private tail → CoW break + only
        // private pages charged on top of the single shared charge
        append_n(&pool, a, 10);
        let priv_a = pool.with_seq(a, |s| s.capacity_bytes()).unwrap();
        assert!(priv_a > 0);
        let s = pool.stats();
        assert_eq!(s.in_use_bytes, bb + priv_a);
        assert_eq!(s.cow_breaks, 1);
        append_n(&pool, a, 5); // still one break per sequence
        assert_eq!(pool.stats().cow_breaks, 1);
        // frees: the base's bytes leave exactly once, on the LAST release
        pool.free(a).unwrap();
        pool.free(b).unwrap();
        let s = pool.stats();
        assert_eq!(s.shared_segs, 1, "still referenced by c");
        assert_eq!(pool.shared_refs(base.id), 1);
        pool.free(c).unwrap();
        let s = pool.stats();
        assert_eq!(s.in_use_bytes, 0);
        assert_eq!(s.shared_segs, 0);
        assert_eq!(s.shared_bytes, 0);
        assert_eq!(pool.shared_refs(base.id), 0);
        assert_eq!(s.page_alloc_bytes - s.page_free_bytes, 0);
    }

    #[test]
    fn attached_admission_is_net_of_resident_base() {
        let p = QuantPolicy::kivi(2, 1);
        let base = mk_base(&p, 70);
        let bb = base.bytes();
        // budget: exactly one base + a little private headroom
        let probe = SeqCache::attach(&base);
        let grow_10 = probe.growth_bytes_for(10);
        let pool = CachePool::new(geo(), bb + 2 * grow_10);
        // not resident yet: admission must charge the base
        assert!(pool.admit_attached(&base, 10).is_ok());
        let a = pool.allocate_attached(&base).unwrap();
        append_n(&pool, a, 10);
        // resident now: a second borrower is admitted NET of the base even
        // though a fresh unshared sequence of the same length would not fit
        assert!(pool.admit(&p, base.n_tokens() + 10).is_err());
        assert!(pool.admit_attached(&base, 10).is_ok());
        let b = pool.allocate_attached(&base).unwrap();
        append_n(&pool, b, 10);
        assert_eq!(pool.stats().in_use_bytes, bb + 2 * grow_10);
        // a standalone (registered-prefix) reference keeps pages resident
        // after all sequences leave
        pool.retain_shared(&base).unwrap();
        pool.free(a).unwrap();
        pool.free(b).unwrap();
        assert_eq!(pool.stats().shared_bytes, bb);
        pool.release_shared(base.id).unwrap();
        assert_eq!(pool.stats().in_use_bytes, 0);
        assert!(pool.release_shared(base.id).is_err(), "double release refused");
    }

    #[test]
    fn shared_refcount_invariants_prop() {
        // random interleavings of attach / grow / standalone retain /
        // release / free over several bases: after EVERY op the pool charge
        // must equal Σ private capacity + Σ unique resident base bytes, and
        // drop-to-zero must free a base's bytes exactly once.
        use crate::util::prop::{check, Gen};
        check("pool_shared_refcounts", 15, |g: &mut Gen| {
            let pool = CachePool::new(geo(), usize::MAX);
            let bases = [
                mk_base(&QuantPolicy::kivi(2, 1), 40),
                mk_base(&QuantPolicy::kivi(2, 2), 70),
                mk_base(&QuantPolicy::float32(2), 33),
            ];
            let mut live: Vec<(u64, usize)> = Vec::new(); // (seq id, base idx)
            let mut standalone: Vec<usize> = Vec::new(); // base idx per retain
            for _ in 0..g.usize_in(8, 30) {
                match g.usize_in(0, 4) {
                    0 => {
                        let bi = g.usize_in(0, bases.len() - 1);
                        let id = pool.allocate_attached(&bases[bi]).unwrap();
                        live.push((id, bi));
                    }
                    1 if !live.is_empty() => {
                        // diverge a random borrower by a small private tail
                        let (id, _) = *g.pick(&live);
                        let n = g.usize_in(1, 20);
                        let fits = pool
                            .with_seq(id, |s| s.pos + n <= 128 + 64)
                            .unwrap();
                        if fits {
                            append_n(&pool, id, n);
                        }
                    }
                    2 => {
                        let bi = g.usize_in(0, bases.len() - 1);
                        pool.retain_shared(&bases[bi]).unwrap();
                        standalone.push(bi);
                    }
                    3 if !standalone.is_empty() => {
                        let i = g.usize_in(0, standalone.len() - 1);
                        let bi = standalone.swap_remove(i);
                        pool.release_shared(bases[bi].id).unwrap();
                    }
                    _ if !live.is_empty() => {
                        let i = g.usize_in(0, live.len() - 1);
                        let (id, _) = live.swap_remove(i);
                        pool.free(id).unwrap();
                    }
                    _ => {}
                }
                let s = pool.stats();
                let private: usize = live
                    .iter()
                    .map(|&(id, _)| pool.with_seq(id, |c| c.capacity_bytes()).unwrap())
                    .sum();
                // unique resident bases = referenced by a live seq OR a
                // standalone retain
                let resident_shared: usize = bases
                    .iter()
                    .enumerate()
                    .filter(|(bi, _)| {
                        live.iter().any(|&(_, b)| b == *bi)
                            || standalone.contains(bi)
                    })
                    .map(|(_, b)| b.bytes())
                    .sum();
                if s.in_use_bytes != private + resident_shared {
                    return Err(format!(
                        "charged {} != private {private} + shared {resident_shared}",
                        s.in_use_bytes
                    ));
                }
                if s.shared_bytes != resident_shared {
                    return Err(format!(
                        "shared_bytes {} != resident {resident_shared}",
                        s.shared_bytes
                    ));
                }
                if s.page_alloc_bytes - s.page_free_bytes != s.in_use_bytes as u64 {
                    return Err(format!(
                        "page ledger off: +{} -{} vs in_use {}",
                        s.page_alloc_bytes, s.page_free_bytes, s.in_use_bytes
                    ));
                }
                // expected refcounts per base
                for (bi, b) in bases.iter().enumerate() {
                    let want = live.iter().filter(|&&(_, x)| x == bi).count()
                        + standalone.iter().filter(|&&x| x == bi).count();
                    if pool.shared_refs(b.id) != want {
                        return Err(format!(
                            "base {bi}: refs {} != expected {want}",
                            pool.shared_refs(b.id)
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
