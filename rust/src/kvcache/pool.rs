//! Cache pool: owns every sequence's per-layer caches, enforces a byte
//! budget, and tracks peak usage — the measurement substrate behind the
//! paper's Fig. 4 (peak GPU memory vs quantization configuration).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use super::layer::{CacheGeometry, LayerCache};
use crate::quant::QuantPolicy;

/// All layers of one sequence's KV cache.
#[derive(Debug, Clone)]
pub struct SeqCache {
    pub layers: Vec<LayerCache>,
    /// absolute position of the next token (tokens seen so far)
    pub pos: usize,
}

impl SeqCache {
    pub fn new(geo: CacheGeometry, policy: &QuantPolicy) -> Self {
        let layers = (0..policy.n_layers())
            .map(|i| LayerCache::new(geo, policy.k_bits[i], policy.v_bits[i]))
            .collect();
        Self { layers, pos: 0 }
    }

    pub fn used_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.used_bytes()).sum()
    }

    pub fn capacity_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.capacity_bytes()).sum()
    }
}

/// Why an allocation was refused (backpressure signal to the scheduler).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    BudgetExceeded { requested: usize, in_use: usize, budget: usize },
    UnknownSeq(u64),
    /// The sequence is pinned (a live session holds it) and cannot be freed.
    Pinned(u64),
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::BudgetExceeded { requested, in_use, budget } => write!(
                f,
                "cache budget exceeded: requested {requested}B, in use {in_use}B, budget {budget}B"
            ),
            PoolError::UnknownSeq(id) => write!(f, "unknown sequence {id}"),
            PoolError::Pinned(id) => {
                write!(f, "sequence {id} is pinned (unpin before freeing)")
            }
        }
    }
}
impl std::error::Error for PoolError {}

/// Thread-safe cache pool with capacity accounting.
///
/// Accounting uses *capacity* bytes (the full static allocation of a
/// sequence's cache) for admission — that is what a real deployment must
/// budget for — while `stats()` additionally reports live `used` bytes.
pub struct CachePool {
    geo: CacheGeometry,
    budget_bytes: usize,
    inner: Mutex<PoolInner>,
}

struct PoolInner {
    seqs: BTreeMap<u64, SeqCache>,
    /// Sequences that refuse `free` until unpinned (session retention).
    pinned: BTreeSet<u64>,
    next_id: u64,
    in_use: usize,
    peak: usize,
    total_allocs: u64,
    total_frees: u64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolStats {
    pub n_seqs: usize,
    pub pinned_seqs: usize,
    pub in_use_bytes: usize,
    pub used_bytes: usize,
    pub peak_bytes: usize,
    pub budget_bytes: usize,
    pub total_allocs: u64,
    pub total_frees: u64,
}

impl CachePool {
    pub fn new(geo: CacheGeometry, budget_bytes: usize) -> Self {
        Self {
            geo,
            budget_bytes,
            inner: Mutex::new(PoolInner {
                seqs: BTreeMap::new(),
                pinned: BTreeSet::new(),
                next_id: 1,
                in_use: 0,
                peak: 0,
                total_allocs: 0,
                total_frees: 0,
            }),
        }
    }

    pub fn geometry(&self) -> CacheGeometry {
        self.geo
    }

    /// Allocate a cache for a new sequence under `policy`.
    pub fn allocate(&self, policy: &QuantPolicy) -> Result<u64, PoolError> {
        let cache = SeqCache::new(self.geo, policy);
        let cap = cache.capacity_bytes();
        let mut inner = self.inner.lock().unwrap();
        if inner.in_use + cap > self.budget_bytes {
            return Err(PoolError::BudgetExceeded {
                requested: cap,
                in_use: inner.in_use,
                budget: self.budget_bytes,
            });
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.in_use += cap;
        inner.peak = inner.peak.max(inner.in_use);
        inner.total_allocs += 1;
        inner.seqs.insert(id, cache);
        Ok(id)
    }

    /// Free a sequence's cache. Pinned sequences are refused — unpin first.
    pub fn free(&self, id: u64) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.seqs.contains_key(&id) {
            return Err(PoolError::UnknownSeq(id));
        }
        if inner.pinned.contains(&id) {
            return Err(PoolError::Pinned(id));
        }
        let cache = inner.seqs.remove(&id).unwrap();
        inner.in_use -= cache.capacity_bytes();
        inner.total_frees += 1;
        Ok(())
    }

    /// Pin a sequence: `free` will refuse it until `unpin`. Guards session
    /// caches against the scheduler's per-request release paths.
    pub fn pin(&self, id: u64) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.seqs.contains_key(&id) {
            return Err(PoolError::UnknownSeq(id));
        }
        inner.pinned.insert(id);
        Ok(())
    }

    pub fn unpin(&self, id: u64) -> Result<(), PoolError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.seqs.contains_key(&id) {
            return Err(PoolError::UnknownSeq(id));
        }
        inner.pinned.remove(&id);
        Ok(())
    }

    /// Run `f` with mutable access to one sequence's cache.
    pub fn with_seq<R>(
        &self,
        id: u64,
        f: impl FnOnce(&mut SeqCache) -> R,
    ) -> Result<R, PoolError> {
        let mut inner = self.inner.lock().unwrap();
        let cache = inner.seqs.get_mut(&id).ok_or(PoolError::UnknownSeq(id))?;
        Ok(f(cache))
    }

    /// Run `f` with mutable access to several sequences at once (batch
    /// assembly). IDs must be distinct.
    pub fn with_seqs<R>(
        &self,
        ids: &[u64],
        f: impl FnOnce(&mut [&mut SeqCache]) -> R,
    ) -> Result<R, PoolError> {
        let mut inner = self.inner.lock().unwrap();
        // split the map into disjoint mutable borrows
        let inner = &mut *inner;
        let mut refs: Vec<*mut SeqCache> = Vec::with_capacity(ids.len());
        for &id in ids {
            let c = inner.seqs.get_mut(&id).ok_or(PoolError::UnknownSeq(id))?;
            let p = c as *mut SeqCache;
            if refs.contains(&p) {
                panic!("duplicate sequence id {id} in batch");
            }
            refs.push(p);
        }
        // SAFETY: all pointers come from distinct keys of the same map and
        // the map is locked for the duration of `f`.
        let mut borrows: Vec<&mut SeqCache> =
            refs.into_iter().map(|p| unsafe { &mut *p }).collect();
        Ok(f(&mut borrows))
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        PoolStats {
            n_seqs: inner.seqs.len(),
            pinned_seqs: inner.pinned.len(),
            in_use_bytes: inner.in_use,
            used_bytes: inner.seqs.values().map(|c| c.used_bytes()).sum(),
            peak_bytes: inner.peak,
            budget_bytes: self.budget_bytes,
            total_allocs: inner.total_allocs,
            total_frees: inner.total_frees,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> CacheGeometry {
        CacheGeometry { n_heads: 2, max_ctx: 128, d_head: 32, group: 32, residual: 64 }
    }

    #[test]
    fn alloc_free_accounting() {
        let pool = CachePool::new(geo(), usize::MAX);
        let p = QuantPolicy::kivi(2, 2);
        let a = pool.allocate(&p).unwrap();
        let b = pool.allocate(&p).unwrap();
        let s = pool.stats();
        assert_eq!(s.n_seqs, 2);
        assert!(s.in_use_bytes > 0);
        assert_eq!(s.in_use_bytes, s.peak_bytes);
        pool.free(a).unwrap();
        let s2 = pool.stats();
        assert_eq!(s2.n_seqs, 1);
        assert_eq!(s2.in_use_bytes, s.in_use_bytes / 2);
        assert_eq!(s2.peak_bytes, s.peak_bytes); // peak sticks
        pool.free(b).unwrap();
        assert_eq!(pool.stats().in_use_bytes, 0);
        assert!(pool.free(b).is_err());
    }

    #[test]
    fn budget_backpressure() {
        let p = QuantPolicy::kivi(2, 2);
        let one = SeqCache::new(geo(), &p).capacity_bytes();
        let pool = CachePool::new(geo(), one * 2 + 1);
        let _a = pool.allocate(&p).unwrap();
        let _b = pool.allocate(&p).unwrap();
        match pool.allocate(&p) {
            Err(PoolError::BudgetExceeded { .. }) => {}
            other => panic!("expected backpressure, got {other:?}"),
        }
    }

    #[test]
    fn policy_changes_capacity() {
        let pool = CachePool::new(geo(), usize::MAX);
        let id_f = pool.allocate(&QuantPolicy::float32(4)).unwrap();
        let cap_f = pool.with_seq(id_f, |c| c.capacity_bytes()).unwrap();
        let id_1 = pool.allocate(&QuantPolicy::kivi(4, 1)).unwrap();
        let cap_1 = pool.with_seq(id_1, |c| c.capacity_bytes()).unwrap();
        // capacity includes the fixed fp32 residual window (R=64 vs
        // T=128 here), so the full 16x data ratio is diluted at this
        // tiny geometry; at the bench geometry (T>>R) the gap widens.
        assert!(cap_1 < cap_f / 2, "1-bit cache should be well below fp32");
    }

    #[test]
    fn pinned_seq_refuses_free_until_unpinned() {
        let pool = CachePool::new(geo(), usize::MAX);
        let p = QuantPolicy::kivi(2, 2);
        let id = pool.allocate(&p).unwrap();
        pool.pin(id).unwrap();
        assert_eq!(pool.stats().pinned_seqs, 1);
        match pool.free(id) {
            Err(PoolError::Pinned(got)) => assert_eq!(got, id),
            other => panic!("expected Pinned, got {other:?}"),
        }
        // still allocated and accessible
        assert_eq!(pool.stats().n_seqs, 1);
        pool.with_seq(id, |c| c.pos).unwrap();
        pool.unpin(id).unwrap();
        assert_eq!(pool.stats().pinned_seqs, 0);
        pool.free(id).unwrap();
        assert_eq!(pool.stats().n_seqs, 0);
        assert!(pool.pin(id).is_err(), "pin of freed seq must fail");
    }

    #[test]
    fn with_seqs_disjoint_access() {
        let pool = CachePool::new(geo(), usize::MAX);
        let p = QuantPolicy::float32(1);
        let a = pool.allocate(&p).unwrap();
        let b = pool.allocate(&p).unwrap();
        let hd = 2 * 32;
        pool.with_seqs(&[a, b], |seqs| {
            seqs[0].layers[0].append_token(&vec![1.0; hd], &vec![1.0; hd]);
            seqs[1].layers[0].append_token(&vec![2.0; hd], &vec![2.0; hd]);
        })
        .unwrap();
        assert_eq!(pool.with_seq(a, |c| c.layers[0].n_res()).unwrap(), 1);
        assert!(pool.with_seqs(&[a, 999], |_| ()).is_err());
    }
}
