//! KV-cache memory substrate: bit-packed per-layer caches with fp32
//! residual windows (KIVI layout) and a budgeted pool with peak tracking.

pub mod hibernate;
pub mod layer;
pub mod pool;
pub mod prefix;

pub use hibernate::{
    HibernateConfig, HibernateError, HibernateImage, HibernateStats,
    HibernateStore,
};
pub use layer::{CacheGeometry, LayerBase, LayerCache};
pub use pool::{CachePool, PoolError, PoolStats, SeqBase, SeqCache};
pub use prefix::{PrefixCache, PrefixEntry, PrefixStats};
