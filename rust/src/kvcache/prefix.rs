//! Prefix cache: reuse prefilled (possibly quantized) KV state across
//! requests that share a prompt prefix — the KV-cache-reuse optimization
//! every production server ships (vLLM "automatic prefix caching"),
//! here operating directly on AsymKV's bit-packed caches: a snapshot stores
//! the packed groups + scales/zeros + fp residual ring as-is, so restoring
//! costs one memcpy per tensor and no requantization.
//!
//! Snapshots are keyed by (policy name, full prompt tokens); a lookup
//! returns the LONGEST entry whose tokens are a prefix of the new prompt.
//! Entries carry the last-position logits so an exact-match request skips
//! prefill entirely. Byte-budgeted with LRU eviction.

use std::sync::{Arc, Mutex};

use super::pool::SeqCache;

pub struct PrefixEntry {
    pub policy: String,
    pub tokens: Vec<i32>,
    pub cache: SeqCache,
    /// logits at the last prompt position (exact-hit fast path)
    pub last_logits: Vec<f32>,
}

/// Resident bytes one entry pins: the snapshot's allocated pages (demand
/// paging means a snapshot stores exactly the pages its prompt grew), the
/// key tokens, AND the vocab-sized logits row — omitting the logits used
/// to let the cache blow past its byte budget by `4·vocab` per entry.
fn entry_bytes(e: &PrefixEntry) -> usize {
    e.cache.capacity_bytes() + e.tokens.len() * 4 + e.last_logits.len() * 4
}

struct Inner {
    /// most-recently-used last
    entries: Vec<Arc<PrefixEntry>>,
    used_bytes: usize,
    hits: u64,
    misses: u64,
}

pub struct PrefixCache {
    budget_bytes: usize,
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixStats {
    pub entries: usize,
    pub used_bytes: usize,
    pub hits: u64,
    pub misses: u64,
}

impl PrefixCache {
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            budget_bytes,
            inner: Mutex::new(Inner {
                entries: Vec::new(),
                used_bytes: 0,
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Longest stored prefix of `prompt` under `policy` (and bumps LRU).
    pub fn lookup(&self, policy: &str, prompt: &[i32]) -> Option<Arc<PrefixEntry>> {
        let mut inner = self.inner.lock().unwrap();
        let mut best: Option<usize> = None;
        for (i, e) in inner.entries.iter().enumerate() {
            if e.policy == policy
                && e.tokens.len() <= prompt.len()
                && prompt[..e.tokens.len()] == e.tokens[..]
                && best.is_none_or(|b| inner.entries[b].tokens.len() < e.tokens.len())
            {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let e = inner.entries.remove(i);
                inner.entries.push(e.clone()); // MRU
                inner.hits += 1;
                Some(e)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Store a snapshot (evicting LRU entries to honour the byte budget).
    /// Duplicate (policy, tokens) keys replace the old entry.
    pub fn insert(&self, entry: PrefixEntry) {
        let bytes = entry_bytes(&entry);
        if bytes > self.budget_bytes {
            return; // would never fit
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(i) = inner
            .entries
            .iter()
            .position(|e| e.policy == entry.policy && e.tokens == entry.tokens)
        {
            let old = inner.entries.remove(i);
            inner.used_bytes -= entry_bytes(&old);
        }
        while inner.used_bytes + bytes > self.budget_bytes && !inner.entries.is_empty() {
            let old = inner.entries.remove(0);
            inner.used_bytes -= entry_bytes(&old);
        }
        inner.used_bytes += bytes;
        inner.entries.push(Arc::new(entry));
    }

    pub fn stats(&self) -> PrefixStats {
        let inner = self.inner.lock().unwrap();
        PrefixStats {
            entries: inner.entries.len(),
            used_bytes: inner.used_bytes,
            hits: inner.hits,
            misses: inner.misses,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::layer::CacheGeometry;
    use crate::quant::QuantPolicy;

    fn geo() -> CacheGeometry {
        CacheGeometry { n_heads: 1, max_ctx: 64, d_head: 32, group: 32, residual: 32 }
    }

    fn entry(policy: &str, tokens: Vec<i32>) -> PrefixEntry {
        PrefixEntry {
            policy: policy.into(),
            tokens,
            cache: SeqCache::new(geo(), &QuantPolicy::kivi(1, 2)),
            last_logits: vec![0.0; 4],
        }
    }

    #[test]
    fn lookup_longest_matching_prefix() {
        let pc = PrefixCache::new(1 << 20);
        pc.insert(entry("kivi", vec![1, 2]));
        pc.insert(entry("kivi", vec![1, 2, 3]));
        pc.insert(entry("float", vec![1, 2, 3, 4]));
        let hit = pc.lookup("kivi", &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(hit.tokens, vec![1, 2, 3]); // longest kivi prefix
        assert!(pc.lookup("kivi", &[9, 9]).is_none());
        // policy must match
        assert_eq!(pc.lookup("float", &[1, 2, 3, 4]).unwrap().tokens.len(), 4);
        let s = pc.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let one = entry_bytes(&entry("p", vec![1]));
        let pc = PrefixCache::new(one * 2 + one / 2);
        pc.insert(entry("p", vec![1]));
        pc.insert(entry("p", vec![2]));
        // touch [1] so [2] becomes LRU
        assert!(pc.lookup("p", &[1, 5]).is_some());
        pc.insert(entry("p", vec![3]));
        assert_eq!(pc.stats().entries, 2);
        assert!(pc.lookup("p", &[2, 5]).is_none(), "LRU entry evicted");
        assert!(pc.lookup("p", &[1, 5]).is_some());
        assert!(pc.lookup("p", &[3, 5]).is_some());
    }

    #[test]
    fn entry_size_includes_logits_regression() {
        // the old accounting omitted `last_logits` (vocab-sized, 4 B per
        // entry here 4 floats; in a real model 4·vocab), so entries whose
        // weight is dominated by logits blew past the budget unbounded
        let mut big = entry("p", vec![1]);
        big.last_logits = vec![0.5; 256];
        let one = entry_bytes(&big);
        assert!(one >= 256 * 4, "logits must dominate this entry's size");
        let pc = PrefixCache::new(one * 2); // room for exactly two
        for t in 0..5 {
            let mut e = entry("p", vec![t]);
            e.last_logits = vec![0.5; 256];
            pc.insert(e);
        }
        let s = pc.stats();
        assert_eq!(s.entries, 2, "logits-aware eviction must kick in");
        assert!(s.used_bytes <= one * 2, "cannot exceed the byte budget");
    }

    #[test]
    fn snapshot_stores_only_allocated_pages() {
        // a snapshot of a short prompt pins only its grown pages, not the
        // full-context footprint it would eventually reach
        let mut e = entry("p", vec![1, 2, 3]);
        let hd = 32; // 1 head × Dh=32
        for _ in 0..3 {
            e.cache.layers[0].append_token(&vec![1.0; hd], &vec![1.0; hd]);
        }
        let snap = e.cache.capacity_bytes();
        assert!(snap > 0);
        // only one ring page is resident; the packed region (the part that
        // scales with T) is entirely unallocated at this depth
        assert!(
            snap < e.cache.full_capacity_bytes(),
            "short snapshot must cost less than the full-context footprint"
        );
        assert_eq!(e.cache.layers[0].q_capacity(), 0);
        let pc = PrefixCache::new(1 << 20);
        pc.insert(e);
        assert_eq!(pc.stats().entries, 1);
    }

    #[test]
    fn restored_snapshot_never_aliases_live_versions() {
        // the engine's staged literal cache validates against LayerCache
        // version stamps; a snapshot restore goes through Clone, which
        // re-stamps every version — so restored state can never be
        // mistaken for the live cache's linear history (full invalidation
        // on prefix-restore, by construction)
        let mut e = entry("p", vec![1, 2]);
        let hd = 32;
        for _ in 0..5 {
            e.cache.layers[0].append_token(&vec![1.0; hd], &vec![2.0; hd]);
        }
        let live = &e.cache.layers[0];
        let pc = PrefixCache::new(1 << 20);
        let (ident, packed, res_base) = (
            live.ident_version(), live.packed_version(), live.res_base_version(),
        );
        pc.insert(e);
        let restored = pc.lookup("p", &[1, 2]).unwrap().cache.clone();
        let rl = &restored.layers[0];
        assert_ne!(rl.ident_version(), ident);
        assert_ne!(rl.packed_version(), packed);
        assert_ne!(rl.res_base_version(), res_base);
    }

    #[test]
    fn duplicate_key_replaces() {
        let pc = PrefixCache::new(1 << 20);
        pc.insert(entry("p", vec![1, 2]));
        let mut e = entry("p", vec![1, 2]);
        e.last_logits = vec![9.0; 4];
        pc.insert(e);
        assert_eq!(pc.stats().entries, 1);
        assert_eq!(pc.lookup("p", &[1, 2]).unwrap().last_logits[0], 9.0);
    }

    #[test]
    fn oversized_entry_ignored() {
        // an empty snapshot still costs tokens.len()·4 + logits bytes; a
        // budget of 2 bytes cannot hold even that
        let pc = PrefixCache::new(2);
        pc.insert(entry("p", vec![1]));
        assert_eq!(pc.stats().entries, 0);
    }
}
