//! Prefix cache: reuse prefilled (possibly quantized) KV state across
//! requests that share a prompt prefix — the KV-cache-reuse optimization
//! every production server ships (vLLM "automatic prefix caching"), here
//! operating directly on AsymKV's bit-packed caches.
//!
//! Entries hold a frozen [`SeqBase`] (an `Arc`-shared all-layer snapshot):
//! a hit ATTACHES the snapshot read-only instead of memcpy'ing it into the
//! borrower, so restore costs zero bytes and N concurrent borrowers pin
//! one copy of the prefix pages (see `pool.rs` for the refcounted charge
//! and copy-on-write accounting). Last-position logits ride along behind
//! an `Arc` so exact-hit requests skip prefill without a vocab-sized copy.
//!
//! Lookups are keyed by (policy fingerprint, token path) and indexed by a
//! **first-group hash**: an entry is bucketed under the hash of its first
//! `FG` tokens (its whole path when shorter), so a lookup probes one
//! bucket for every long candidate plus at most `FG` short buckets,
//! instead of linearly rescanning every entry's full token vector. The
//! longest stored prefix of the prompt wins.
//!
//! Anonymous entries (auto-snapshotted after prefill) are byte-budgeted
//! with LRU eviction. **Named** entries — registered through the v3
//! `prefix_register` op — are pinned: exempt from the budget and from
//! eviction (their pages are charged to the POOL via a standalone shared
//! reference their owner holds), released only by `prefix_release`.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::pool::SeqBase;

/// First-group width of the lookup index: entries are bucketed by the hash
/// of their first `FG` tokens (matches the packed-group size the caches
/// quantize at, so "same first group" ≈ "same first packed page").
const FG: usize = 32;

fn fg_hash(policy: &str, toks: &[i32]) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    policy.hash(&mut h);
    toks.hash(&mut h);
    h.finish()
}

pub struct PrefixEntry {
    pub policy: String,
    pub tokens: Vec<i32>,
    /// Frozen, immutable KV snapshot; borrowers attach it zero-copy.
    pub base: Arc<SeqBase>,
    /// Logits at the last prompt position (exact-hit fast path) — shared,
    /// never deep-copied per hit.
    pub last_logits: Arc<Vec<f32>>,
    /// Pin name (`prefix_register`); `Some` exempts the entry from LRU
    /// eviction and the byte budget.
    pub name: Option<String>,
    /// Times this entry seeded a request (lookup hits + named attaches).
    uses: AtomicU64,
    /// LRU recency stamp (cache-internal tick).
    last_used: AtomicU64,
}

impl PrefixEntry {
    pub fn new(
        policy: String,
        tokens: Vec<i32>,
        base: Arc<SeqBase>,
        last_logits: Arc<Vec<f32>>,
    ) -> Self {
        Self {
            policy,
            tokens,
            base,
            last_logits,
            name: None,
            uses: AtomicU64::new(0),
            last_used: AtomicU64::new(0),
        }
    }

    pub fn named(
        name: String,
        policy: String,
        tokens: Vec<i32>,
        base: Arc<SeqBase>,
        last_logits: Arc<Vec<f32>>,
    ) -> Self {
        Self { name: Some(name), ..Self::new(policy, tokens, base, last_logits) }
    }

    pub fn is_pinned(&self) -> bool {
        self.name.is_some()
    }

    pub fn uses(&self) -> u64 {
        self.uses.load(Ordering::Relaxed)
    }

    fn bucket_key(&self) -> u64 {
        fg_hash(&self.policy, &self.tokens[..self.tokens.len().min(FG)])
    }
}

/// Resident bytes one anonymous entry pins: the snapshot's buffers (frozen
/// snapshots store exactly the state their prompt grew), the key tokens,
/// AND the vocab-sized logits row — omitting the logits used to let the
/// cache blow past its byte budget by `4·vocab` per entry.
fn entry_bytes(e: &PrefixEntry) -> usize {
    e.base.bytes() + e.tokens.len() * 4 + e.last_logits.len() * 4
}

struct Inner {
    /// first-group hash → entries sharing that leading token group
    buckets: HashMap<u64, Vec<Arc<PrefixEntry>>>,
    /// registered (pinned) entries by name; each is also in `buckets` so
    /// anonymous prefix lookups hit it too
    named: HashMap<String, Arc<PrefixEntry>>,
    /// Σ entry_bytes over UNPINNED entries (the budgeted population)
    used_bytes: usize,
    hits: u64,
    misses: u64,
    tick: u64,
}

impl Inner {
    fn touch(&mut self, e: &Arc<PrefixEntry>) {
        self.tick += 1;
        e.last_used.store(self.tick, Ordering::Relaxed);
        e.uses.fetch_add(1, Ordering::Relaxed);
        self.hits += 1;
    }

    fn remove_entry(&mut self, victim: &Arc<PrefixEntry>) {
        let key = victim.bucket_key();
        if let Some(bucket) = self.buckets.get_mut(&key) {
            bucket.retain(|e| !Arc::ptr_eq(e, victim));
            if bucket.is_empty() {
                self.buckets.remove(&key);
            }
        }
    }

    /// Evict the least-recently-used UNPINNED entry. False when none left.
    fn evict_lru(&mut self) -> bool {
        let mut victim: Option<(Arc<PrefixEntry>, u64)> = None;
        for bucket in self.buckets.values() {
            for e in bucket {
                if e.is_pinned() {
                    continue;
                }
                let lu = e.last_used.load(Ordering::Relaxed);
                if victim.as_ref().is_none_or(|(_, v)| lu < *v) {
                    victim = Some((e.clone(), lu));
                }
            }
        }
        let Some((victim, _)) = victim else { return false };
        self.used_bytes -= entry_bytes(&victim);
        self.remove_entry(&victim);
        true
    }
}

pub struct PrefixCache {
    budget_bytes: usize,
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixStats {
    pub entries: usize,
    /// Registered (pinned) entries — subset of `entries`.
    pub named: usize,
    pub used_bytes: usize,
    pub hits: u64,
    pub misses: u64,
}

impl PrefixCache {
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            budget_bytes,
            inner: Mutex::new(Inner {
                buckets: HashMap::new(),
                named: HashMap::new(),
                used_bytes: 0,
                hits: 0,
                misses: 0,
                tick: 0,
            }),
        }
    }

    /// Longest stored prefix of `prompt` under `policy` (bumps LRU + use
    /// counts). Probes the full-first-group bucket for long candidates,
    /// then (only if none matched) the at-most-`FG` short buckets, longest
    /// first — any long match beats every possible short one.
    pub fn lookup(&self, policy: &str, prompt: &[i32]) -> Option<Arc<PrefixEntry>> {
        let mut inner = self.inner.lock().unwrap();
        let mut best: Option<Arc<PrefixEntry>> = None;
        if prompt.len() >= FG {
            if let Some(bucket) = inner.buckets.get(&fg_hash(policy, &prompt[..FG])) {
                for e in bucket {
                    if e.policy == policy
                        && e.tokens.len() <= prompt.len()
                        && prompt[..e.tokens.len()] == e.tokens[..]
                        && best
                            .as_ref()
                            .is_none_or(|b| b.tokens.len() < e.tokens.len())
                    {
                        best = Some(e.clone());
                    }
                }
            }
        }
        if best.is_none() {
            let kmax = prompt.len().min(FG - 1);
            for k in (0..=kmax).rev() {
                let Some(bucket) = inner.buckets.get(&fg_hash(policy, &prompt[..k]))
                else {
                    continue;
                };
                if let Some(e) = bucket.iter().find(|e| {
                    e.policy == policy
                        && e.tokens.len() == k
                        && e.tokens[..] == prompt[..k]
                }) {
                    best = Some(e.clone());
                    break;
                }
            }
        }
        match best {
            Some(e) => {
                inner.touch(&e);
                Some(e)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Store an anonymous snapshot (evicting LRU unpinned entries to honour
    /// the byte budget). Duplicate (policy, tokens) keys replace the old
    /// entry — unless the incumbent is pinned, which already serves the key.
    pub fn insert(&self, entry: PrefixEntry) {
        debug_assert!(entry.name.is_none(), "use register() for named prefixes");
        let bytes = entry_bytes(&entry);
        if bytes > self.budget_bytes {
            return; // would never fit
        }
        let mut inner = self.inner.lock().unwrap();
        let key = entry.bucket_key();
        if let Some(bucket) = inner.buckets.get_mut(&key) {
            if let Some(i) = bucket
                .iter()
                .position(|e| e.policy == entry.policy && e.tokens == entry.tokens)
            {
                if bucket[i].is_pinned() {
                    return;
                }
                let old = bucket.remove(i);
                inner.used_bytes -= entry_bytes(&old);
            }
        }
        while inner.used_bytes + bytes > self.budget_bytes {
            if !inner.evict_lru() {
                break;
            }
        }
        inner.tick += 1;
        let e = Arc::new(entry);
        e.last_used.store(inner.tick, Ordering::Relaxed);
        inner.used_bytes += bytes;
        inner.buckets.entry(key).or_default().push(e);
    }

    /// Register a pinned, named prefix. Replaces any existing registration
    /// of the same name and subsumes an anonymous duplicate of its (policy,
    /// tokens). Returns the stored entry plus the displaced registration
    /// (whose owner must drop its pool reference).
    pub fn register(
        &self,
        entry: PrefixEntry,
    ) -> (Arc<PrefixEntry>, Option<Arc<PrefixEntry>>) {
        let name = entry.name.clone().expect("register() needs a named entry");
        let mut inner = self.inner.lock().unwrap();
        let displaced = inner.named.remove(&name);
        if let Some(old) = displaced.as_ref() {
            inner.remove_entry(old);
        }
        let key = entry.bucket_key();
        if let Some(bucket) = inner.buckets.get_mut(&key) {
            if let Some(i) = bucket.iter().position(|e| {
                !e.is_pinned() && e.policy == entry.policy && e.tokens == entry.tokens
            }) {
                let old = bucket.remove(i);
                inner.used_bytes -= entry_bytes(&old);
            }
        }
        inner.tick += 1;
        let e = Arc::new(entry);
        e.last_used.store(inner.tick, Ordering::Relaxed);
        inner.buckets.entry(key).or_default().push(e.clone());
        inner.named.insert(name, e.clone());
        (e, displaced)
    }

    /// Drop a registration; the caller releases the pool reference it holds
    /// for the returned entry. `None` if the name is unknown.
    pub fn release(&self, name: &str) -> Option<Arc<PrefixEntry>> {
        let mut inner = self.inner.lock().unwrap();
        let old = inner.named.remove(name)?;
        inner.remove_entry(&old);
        Some(old)
    }

    /// Resolve a registered prefix by name (bumps use counts — callers
    /// attach the result).
    pub fn get_named(&self, name: &str) -> Option<Arc<PrefixEntry>> {
        let mut inner = self.inner.lock().unwrap();
        let e = inner.named.get(name)?.clone();
        inner.touch(&e);
        Some(e)
    }

    /// Registered prefixes, name-sorted (the `prefixes` listing op).
    pub fn list_named(&self) -> Vec<Arc<PrefixEntry>> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<_> = inner.named.values().cloned().collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    pub fn stats(&self) -> PrefixStats {
        let inner = self.inner.lock().unwrap();
        PrefixStats {
            entries: inner.buckets.values().map(|b| b.len()).sum(),
            named: inner.named.len(),
            used_bytes: inner.used_bytes,
            hits: inner.hits,
            misses: inner.misses,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::layer::CacheGeometry;
    use crate::kvcache::pool::SeqCache;
    use crate::quant::QuantPolicy;

    fn geo() -> CacheGeometry {
        CacheGeometry { n_heads: 1, max_ctx: 64, d_head: 32, group: 32, residual: 32 }
    }

    /// Frozen n-token base under the 1-layer kivi(1,2) test policy.
    fn base_n(n: usize) -> Arc<SeqBase> {
        let mut donor = SeqCache::new(geo(), &QuantPolicy::kivi(1, 2));
        for layer in &mut donor.layers {
            for _ in 0..n {
                layer.append_token(&vec![1.0; 32], &vec![1.0; 32]);
            }
        }
        donor.pos = n;
        Arc::new(SeqBase::freeze(&donor))
    }

    fn entry(policy: &str, tokens: Vec<i32>) -> PrefixEntry {
        let base = base_n(tokens.len());
        PrefixEntry::new(policy.into(), tokens, base, Arc::new(vec![0.0; 4]))
    }

    #[test]
    fn lookup_longest_matching_prefix() {
        let pc = PrefixCache::new(1 << 20);
        pc.insert(entry("kivi", vec![1, 2]));
        pc.insert(entry("kivi", vec![1, 2, 3]));
        pc.insert(entry("float", vec![1, 2, 3, 4]));
        let hit = pc.lookup("kivi", &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(hit.tokens, vec![1, 2, 3]); // longest kivi prefix
        assert!(pc.lookup("kivi", &[9, 9]).is_none());
        // policy must match
        assert_eq!(pc.lookup("float", &[1, 2, 3, 4]).unwrap().tokens.len(), 4);
        let s = pc.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn lookup_spans_first_group_boundary() {
        // entries longer than FG live in the full-first-group bucket;
        // shorter ones in exact-path buckets — both must be found, and a
        // long match must beat every short one
        let long: Vec<i32> = (0..40).collect();
        let short: Vec<i32> = (0..5).collect();
        let pc = PrefixCache::new(1 << 20);
        pc.insert(entry("p", short.clone()));
        pc.insert(entry("p", long.clone()));
        let prompt: Vec<i32> = (0..64).collect();
        assert_eq!(pc.lookup("p", &prompt).unwrap().tokens.len(), 40);
        // a prompt diverging inside the first group falls back to the
        // short-bucket probe
        let mut diverged = prompt.clone();
        diverged[20] = 999;
        assert_eq!(pc.lookup("p", &diverged).unwrap().tokens.len(), 5);
        // FG-boundary exactness: prompt shorter than the long entry
        assert_eq!(pc.lookup("p", &prompt[..33]).unwrap().tokens.len(), 5);
    }

    #[test]
    fn exact_hit_shares_logits_arc() {
        let pc = PrefixCache::new(1 << 20);
        pc.insert(entry("p", vec![1, 2, 3]));
        let a = pc.lookup("p", &[1, 2, 3]).unwrap();
        let b = pc.lookup("p", &[1, 2, 3]).unwrap();
        // the logits row is handed out Arc-shared, never deep-copied
        assert!(Arc::ptr_eq(&a.last_logits, &b.last_logits));
        assert!(Arc::ptr_eq(&a.base, &b.base));
        assert_eq!(a.uses(), 2);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let one = entry_bytes(&entry("p", vec![1]));
        let pc = PrefixCache::new(one * 2 + one / 2);
        pc.insert(entry("p", vec![1]));
        pc.insert(entry("p", vec![2]));
        // touch [1] so [2] becomes LRU
        assert!(pc.lookup("p", &[1, 5]).is_some());
        pc.insert(entry("p", vec![3]));
        assert_eq!(pc.stats().entries, 2);
        assert!(pc.lookup("p", &[2, 5]).is_none(), "LRU entry evicted");
        assert!(pc.lookup("p", &[1, 5]).is_some());
        assert!(pc.lookup("p", &[3, 5]).is_some());
    }

    #[test]
    fn entry_size_includes_logits_regression() {
        // the old accounting omitted `last_logits` (vocab-sized, 4 B per
        // entry here 4 floats; in a real model 4·vocab), so entries whose
        // weight is dominated by logits blew past the budget unbounded
        let mut big = entry("p", vec![1]);
        big.last_logits = Arc::new(vec![0.5; 256]);
        let one = entry_bytes(&big);
        assert!(one >= 256 * 4, "logits must dominate this entry's size");
        let pc = PrefixCache::new(one * 2); // room for exactly two
        for t in 0..5 {
            let mut e = entry("p", vec![t]);
            e.last_logits = Arc::new(vec![0.5; 256]);
            pc.insert(e);
        }
        let s = pc.stats();
        assert_eq!(s.entries, 2, "logits-aware eviction must kick in");
        assert!(s.used_bytes <= one * 2, "cannot exceed the byte budget");
    }

    #[test]
    fn named_entries_pinned_against_eviction() {
        let one = entry_bytes(&entry("p", vec![1]));
        let pc = PrefixCache::new(one + one / 2); // room for ONE anonymous
        let mut sys = entry("p", vec![7, 8]);
        sys.name = Some("sys".into());
        pc.register(sys);
        // anonymous churn cannot evict the pinned entry
        pc.insert(entry("p", vec![1]));
        pc.insert(entry("p", vec![2]));
        let s = pc.stats();
        assert_eq!(s.named, 1);
        assert_eq!(s.entries, 2, "pinned + one surviving anonymous");
        assert!(s.used_bytes <= one, "pinned entry not budget-counted");
        // the pinned entry serves anonymous lookups too
        assert_eq!(pc.lookup("p", &[7, 8, 9]).unwrap().tokens, vec![7, 8]);
        assert!(pc.get_named("sys").is_some());
        assert_eq!(pc.list_named().len(), 1);
        // release drops it from both the name map and the lookup index
        let released = pc.release("sys").unwrap();
        assert_eq!(released.tokens, vec![7, 8]);
        assert!(pc.get_named("sys").is_none());
        assert!(pc.release("sys").is_none(), "double release is None");
        assert!(pc.lookup("p", &[7, 8, 9]).is_none());
    }

    #[test]
    fn register_replaces_same_name_and_subsumes_anonymous() {
        let pc = PrefixCache::new(1 << 20);
        pc.insert(entry("p", vec![1, 2])); // anonymous duplicate key
        let mut a = entry("p", vec![1, 2]);
        a.name = Some("sys".into());
        let (_, displaced) = pc.register(a);
        assert!(displaced.is_none());
        assert_eq!(pc.stats().entries, 1, "anonymous duplicate subsumed");
        assert_eq!(pc.stats().used_bytes, 0);
        // re-registering the same name hands back the displaced entry
        let mut b = entry("p", vec![3, 4]);
        b.name = Some("sys".into());
        let (_, displaced) = pc.register(b);
        assert_eq!(displaced.unwrap().tokens, vec![1, 2]);
        assert_eq!(pc.get_named("sys").unwrap().tokens, vec![3, 4]);
        assert_eq!(pc.stats().entries, 1);
        // an anonymous insert under a pinned key is a no-op
        pc.insert(entry("p", vec![3, 4]));
        assert_eq!(pc.stats().entries, 1);
        assert_eq!(pc.stats().used_bytes, 0);
    }

    #[test]
    fn snapshot_stores_only_allocated_pages() {
        // a frozen base stores exactly the state its prompt grew — far less
        // than the full-context footprint a fully-grown cache would pin
        let e = entry("p", vec![1, 2, 3]);
        assert!(e.base.bytes() > 0);
        assert!(
            e.base.bytes()
                < SeqCache::new(geo(), &QuantPolicy::kivi(1, 2)).full_capacity_bytes(),
            "short snapshot must cost less than the full-context footprint"
        );
        assert_eq!(e.base.n_tokens(), 3);
        let pc = PrefixCache::new(1 << 20);
        pc.insert(e);
        assert_eq!(pc.stats().entries, 1);
    }

    #[test]
    fn restored_snapshot_never_aliases_live_versions() {
        // the engine's staged literal cache validates against LayerCache
        // version stamps; attaching a base builds a FRESH LayerCache with
        // fresh stamps — so restored state can never be mistaken for any
        // other sequence's linear history
        let mut donor = SeqCache::new(geo(), &QuantPolicy::kivi(1, 2));
        for _ in 0..5 {
            donor.layers[0].append_token(&vec![1.0; 32], &vec![2.0; 32]);
        }
        donor.pos = 5;
        let live = &donor.layers[0];
        let (ident, packed, res_base) =
            (live.ident_version(), live.packed_version(), live.res_base_version());
        let base = Arc::new(SeqBase::freeze(&donor));
        let pc = PrefixCache::new(1 << 20);
        pc.insert(PrefixEntry::new("p".into(), vec![1, 2], base, Arc::new(vec![])));
        let restored = SeqCache::attach(&pc.lookup("p", &[1, 2]).unwrap().base);
        let rl = &restored.layers[0];
        assert_ne!(rl.ident_version(), ident);
        assert_ne!(rl.packed_version(), packed);
        assert_ne!(rl.res_base_version(), res_base);
        assert_eq!(restored.pos, 5);
    }

    #[test]
    fn duplicate_key_replaces() {
        let pc = PrefixCache::new(1 << 20);
        pc.insert(entry("p", vec![1, 2]));
        let mut e = entry("p", vec![1, 2]);
        e.last_logits = Arc::new(vec![9.0; 4]);
        pc.insert(e);
        assert_eq!(pc.stats().entries, 1);
        assert_eq!(pc.lookup("p", &[1, 2]).unwrap().last_logits[0], 9.0);
    }

    #[test]
    fn oversized_entry_ignored() {
        // an empty snapshot still costs tokens.len()·4 + logits bytes; a
        // budget of 2 bytes cannot hold even that
        let pc = PrefixCache::new(2);
        pc.insert(entry("p", vec![1]));
        assert_eq!(pc.stats().entries, 0);
    }
}
