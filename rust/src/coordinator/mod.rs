//! The serving coordinator: the L3 system contribution — request routing,
//! policy-aware dynamic batching, a continuous-batching scheduler over the
//! AsymKV engine, and serving metrics.
//!
//! ```text
//! clients → Coordinator::submit → RequestQueue (priority, FIFO)
//!                                     │  policy-homogeneous groups
//!                              scheduler thread
//!                prefill batch ─► Engine ─► decode steps (continuous)
//!                                     │
//!                              ResponseHandle ◄ tokens + timing
//! ```

pub mod metrics;
pub mod queue;
pub mod request;
pub mod scheduler;

use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::engine::Engine;
use crate::kvcache::PrefixEntry;
use crate::quant::QuantPolicy;

pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{AbortHandle, AbortKind, Request, Response, ResponseHandle, Timing};
pub use scheduler::CoordinatorConfig;

use queue::RequestQueue;
use request::InFlight;
use scheduler::{run_scheduler, Shared};

/// Descriptor of one registered (named, pinned) shared prefix — the
/// `prefixes` listing op and the `prefix_register` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixInfo {
    pub name: String,
    /// Tokens the shared node covers (an attached request starts here).
    pub n_tokens: usize,
    /// Per-layer bits fingerprint (`"k:v,k:v,…"`) attachers must match.
    pub policy: String,
    /// Live pool references: the registration's own standalone reference
    /// plus one per currently attached sequence.
    pub refcount: usize,
    /// Snapshot bytes — charged ONCE however many sequences map the node.
    pub shared_bytes: usize,
    /// Times this node was handed out (lookups + `prefix_id` resolutions).
    pub hits: u64,
}

/// Typed failures of the first-class prefix ops; the API layer maps these
/// onto stable wire error codes (`unknown_prefix`,
/// `prefix_policy_mismatch`, …).
#[derive(Debug, Clone, PartialEq)]
pub enum PrefixOpError {
    /// The prefix cache is disabled (`prefix_cache_bytes == 0`).
    Disabled,
    /// No registration under that name.
    Unknown(String),
    /// The request's policy does not match the registered node's bits.
    PolicyMismatch { name: String, registered: String, requested: String },
    /// Engine/pool failure while prefilling or pinning the node.
    Failed(String),
}

impl std::fmt::Display for PrefixOpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PrefixOpError::Disabled => {
                write!(f, "prefix cache disabled (prefix_cache_bytes = 0)")
            }
            PrefixOpError::Unknown(name) => write!(f, "unknown prefix '{name}'"),
            PrefixOpError::PolicyMismatch { name, registered, requested } => {
                write!(
                    f,
                    "prefix '{name}' is registered under policy bits \
                     [{registered}] but the request resolves to [{requested}]"
                )
            }
            PrefixOpError::Failed(msg) => write!(f, "prefix op failed: {msg}"),
        }
    }
}

impl std::error::Error for PrefixOpError {}

pub struct Coordinator {
    shared: Arc<Shared>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl Coordinator {
    /// Spawn the scheduler thread over `engine`.
    pub fn start(engine: Arc<Engine>, cfg: CoordinatorConfig) -> Arc<Self> {
        let prefix_cache = (cfg.prefix_cache_bytes > 0)
            .then(|| crate::kvcache::PrefixCache::new(cfg.prefix_cache_bytes));
        let shared = Arc::new(Shared {
            engine,
            queue: Mutex::new(RequestQueue::default()),
            cv: Condvar::new(),
            shutdown: std::sync::atomic::AtomicBool::new(false),
            metrics: Metrics::default(),
            cfg,
            prefix_cache,
        });
        shared.metrics.start_clock();
        let worker = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("asymkv-sched".into())
                .spawn(move || run_scheduler(shared))
                .expect("spawning scheduler")
        };
        Arc::new(Self { shared, worker: Mutex::new(Some(worker)) })
    }

    /// Submit a request; returns immediately with a completion handle.
    pub fn submit(&self, req: Request) -> ResponseHandle {
        let handle = ResponseHandle::new();
        let inf = InFlight::new(req, handle.clone());
        self.shared.queue.lock().unwrap().push(inf);
        self.shared.cv.notify_all();
        handle
    }

    /// Submit and block until completion.
    pub fn submit_wait(&self, req: Request) -> Response {
        self.submit(req).wait()
    }

    /// Wake the scheduler without submitting work. Call after setting a
    /// request's abort flag so a sleeping (or capacity-blocked) scheduler
    /// runs its abort sweep promptly instead of on the next natural wake.
    pub fn kick(&self) {
        self.shared.cv.notify_all();
        self.shared.engine.pool.notify_free();
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.shared.metrics.snapshot();
        snap.engine = self.shared.engine.stats();
        snap
    }

    // Serving-layer counters (recorded by the api subsystem, which owns
    // batch fan-out and the session table but not the metrics registry).

    pub fn note_batch_submit(&self, items: usize) {
        self.shared.metrics.record_batch_submit(items);
    }

    pub fn note_session_opened(&self) {
        self.shared.metrics.record_session_opened();
    }

    pub fn note_session_closed(&self) {
        self.shared.metrics.record_session_closed();
    }

    pub fn note_session_evicted(&self) {
        self.shared.metrics.record_session_evicted();
    }

    /// A tagged (v3) request entered flight at the serving front end.
    pub fn note_inflight_start(&self) {
        self.shared.metrics.record_inflight_start();
    }

    /// A tagged (v3) request's final frame was queued.
    pub fn note_inflight_end(&self) {
        self.shared.metrics.record_inflight_end();
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.shared.engine
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Prefix-cache statistics (None when disabled).
    pub fn prefix_stats(&self) -> Option<crate::kvcache::PrefixStats> {
        self.shared.prefix_cache.as_ref().map(|p| p.stats())
    }

    // -----------------------------------------------------------------
    // first-class shared prefixes (prefix_register / prefix_release /
    // prefixes, and prefix_id resolution for generate / session_open)
    // -----------------------------------------------------------------

    /// Prefill `tokens` once under `policy` and pin the frozen result as a
    /// named shared node: its pages stay resident (one standalone pool
    /// reference, exempt from prefix-cache eviction) until released, and
    /// every request naming it attaches read-only with zero bytes copied.
    /// Re-registering a name replaces the old node and drops its pin.
    pub fn register_prefix(
        &self,
        name: &str,
        tokens: Vec<i32>,
        policy: &QuantPolicy,
    ) -> Result<PrefixInfo, PrefixOpError> {
        let pc = self
            .shared
            .prefix_cache
            .as_ref()
            .ok_or(PrefixOpError::Disabled)?;
        let fingerprint = crate::engine::policy_fingerprint(policy);
        let (base, logits) = self
            .shared
            .engine
            .prefill_shared_base(policy, &tokens)
            .map_err(|e| PrefixOpError::Failed(e.to_string()))?;
        let entry = PrefixEntry::named(
            name.to_string(),
            fingerprint,
            tokens,
            base,
            logits,
        );
        let (entry, displaced) = pc.register(entry);
        if let Some(old) = displaced {
            // the replaced registration held its own standalone reference
            let _ = self.shared.engine.pool.release_shared(old.base.id);
        }
        Ok(self.prefix_info(&entry))
    }

    /// Drop a registration: the node disappears from the listing and its
    /// standalone pool reference is released. Pages stay resident while
    /// already-attached sequences still map them (refcount > 0) and are
    /// freed exactly once when the last reference drops.
    pub fn release_prefix(&self, name: &str) -> Result<PrefixInfo, PrefixOpError> {
        let pc = self
            .shared
            .prefix_cache
            .as_ref()
            .ok_or(PrefixOpError::Disabled)?;
        let entry = pc
            .release(name)
            .ok_or_else(|| PrefixOpError::Unknown(name.to_string()))?;
        let info = self.prefix_info(&entry);
        let _ = self.shared.engine.pool.release_shared(entry.base.id);
        Ok(info)
    }

    /// All registered prefixes, name-sorted.
    pub fn list_prefixes(&self) -> Vec<PrefixInfo> {
        self.shared.prefix_cache.as_ref().map_or_else(Vec::new, |pc| {
            pc.list_named().iter().map(|e| self.prefix_info(e)).collect()
        })
    }

    /// Resolve a `prefix_id` WITHOUT a policy check: used when the request
    /// names no policy and simply adopts the node's per-layer bits.
    pub fn lookup_prefix(
        &self,
        name: &str,
    ) -> Result<Arc<PrefixEntry>, PrefixOpError> {
        let pc = self
            .shared
            .prefix_cache
            .as_ref()
            .ok_or(PrefixOpError::Disabled)?;
        pc.get_named(name)
            .ok_or_else(|| PrefixOpError::Unknown(name.to_string()))
    }

    /// Resolve a `prefix_id` to its shared node, checking the request's
    /// policy against the node's per-layer bits (attaching under different
    /// bits would mis-decode the packed pages).
    pub fn resolve_prefix(
        &self,
        name: &str,
        policy: &QuantPolicy,
    ) -> Result<Arc<PrefixEntry>, PrefixOpError> {
        let pc = self
            .shared
            .prefix_cache
            .as_ref()
            .ok_or(PrefixOpError::Disabled)?;
        let entry = pc
            .get_named(name)
            .ok_or_else(|| PrefixOpError::Unknown(name.to_string()))?;
        let requested = crate::engine::policy_fingerprint(policy);
        if entry.policy != requested {
            return Err(PrefixOpError::PolicyMismatch {
                name: name.to_string(),
                registered: entry.policy.clone(),
                requested,
            });
        }
        Ok(entry)
    }

    fn prefix_info(&self, e: &Arc<PrefixEntry>) -> PrefixInfo {
        PrefixInfo {
            name: e.name.clone().unwrap_or_default(),
            n_tokens: e.tokens.len(),
            policy: e.policy.clone(),
            refcount: self.shared.engine.pool.shared_refs(e.base.id),
            shared_bytes: e.base.bytes(),
            hits: e.uses(),
        }
    }

    /// Graceful shutdown: finish in-flight work, then join the scheduler.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        // the scheduler may be blocked on pool capacity, not the queue
        self.shared.engine.pool.notify_free();
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}
