//! The serving coordinator: the L3 system contribution — request routing,
//! policy-aware dynamic batching, a continuous-batching scheduler over the
//! AsymKV engine, and serving metrics.
//!
//! ```text
//! clients → Coordinator::submit → RequestQueue (priority, FIFO)
//!                                     │  policy-homogeneous groups
//!                              scheduler thread
//!                prefill batch ─► Engine ─► decode steps (continuous)
//!                                     │
//!                              ResponseHandle ◄ tokens + timing
//! ```

pub mod metrics;
pub mod queue;
pub mod request;
pub mod scheduler;

use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::engine::Engine;

pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{AbortHandle, AbortKind, Request, Response, ResponseHandle, Timing};
pub use scheduler::CoordinatorConfig;

use queue::RequestQueue;
use request::InFlight;
use scheduler::{run_scheduler, Shared};

pub struct Coordinator {
    shared: Arc<Shared>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl Coordinator {
    /// Spawn the scheduler thread over `engine`.
    pub fn start(engine: Arc<Engine>, cfg: CoordinatorConfig) -> Arc<Self> {
        let prefix_cache = (cfg.prefix_cache_bytes > 0)
            .then(|| crate::kvcache::PrefixCache::new(cfg.prefix_cache_bytes));
        let shared = Arc::new(Shared {
            engine,
            queue: Mutex::new(RequestQueue::default()),
            cv: Condvar::new(),
            shutdown: std::sync::atomic::AtomicBool::new(false),
            metrics: Metrics::default(),
            cfg,
            prefix_cache,
        });
        shared.metrics.start_clock();
        let worker = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("asymkv-sched".into())
                .spawn(move || run_scheduler(shared))
                .expect("spawning scheduler")
        };
        Arc::new(Self { shared, worker: Mutex::new(Some(worker)) })
    }

    /// Submit a request; returns immediately with a completion handle.
    pub fn submit(&self, req: Request) -> ResponseHandle {
        let handle = ResponseHandle::new();
        let inf = InFlight::new(req, handle.clone());
        self.shared.queue.lock().unwrap().push(inf);
        self.shared.cv.notify_all();
        handle
    }

    /// Submit and block until completion.
    pub fn submit_wait(&self, req: Request) -> Response {
        self.submit(req).wait()
    }

    /// Wake the scheduler without submitting work. Call after setting a
    /// request's abort flag so a sleeping (or capacity-blocked) scheduler
    /// runs its abort sweep promptly instead of on the next natural wake.
    pub fn kick(&self) {
        self.shared.cv.notify_all();
        self.shared.engine.pool.notify_free();
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.shared.metrics.snapshot();
        snap.engine = self.shared.engine.stats();
        snap
    }

    // Serving-layer counters (recorded by the api subsystem, which owns
    // batch fan-out and the session table but not the metrics registry).

    pub fn note_batch_submit(&self, items: usize) {
        self.shared.metrics.record_batch_submit(items);
    }

    pub fn note_session_opened(&self) {
        self.shared.metrics.record_session_opened();
    }

    pub fn note_session_closed(&self) {
        self.shared.metrics.record_session_closed();
    }

    pub fn note_session_evicted(&self) {
        self.shared.metrics.record_session_evicted();
    }

    /// A tagged (v3) request entered flight at the serving front end.
    pub fn note_inflight_start(&self) {
        self.shared.metrics.record_inflight_start();
    }

    /// A tagged (v3) request's final frame was queued.
    pub fn note_inflight_end(&self) {
        self.shared.metrics.record_inflight_end();
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.shared.engine
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Prefix-cache statistics (None when disabled).
    pub fn prefix_stats(&self) -> Option<crate::kvcache::PrefixStats> {
        self.shared.prefix_cache.as_ref().map(|p| p.stats())
    }

    /// Graceful shutdown: finish in-flight work, then join the scheduler.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        // the scheduler may be blocked on pool capacity, not the queue
        self.shared.engine.pool.notify_free();
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}
