//! Serving metrics: counters + latency reservoirs, exported as JSON by the
//! server's /stats verb and printed by the perf benches.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Value;
use crate::util::stats::percentile;

#[derive(Default)]
struct Inner {
    started: Option<Instant>,
    requests_completed: u64,
    requests_failed: u64,
    preemptions: u64,
    downshifts: u64,
    downshift_bytes_freed: u64,
    cancelled: u64,
    deadline_expired: u64,
    /// Tagged requests currently in flight across all connections
    /// (registered by the server when a request starts, released when its
    /// final frame is queued).
    inflight_now: u64,
    inflight_peak: u64,
    tokens_generated: u64,
    prefill_tokens: u64,
    batch_requests: u64,
    batch_items: u64,
    sessions_opened: u64,
    sessions_closed: u64,
    sessions_evicted: u64,
    batch_sizes: Vec<f64>,
    queue_s: Vec<f64>,
    ttft_s: Vec<f64>,
    total_s: Vec<f64>,
    decode_step_s: Vec<f64>,
}

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn start_clock(&self) {
        let mut m = self.inner.lock().unwrap();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
    }

    pub fn record_completion(&self, timing: &super::request::Timing, n_tokens: usize) {
        let mut m = self.inner.lock().unwrap();
        m.requests_completed += 1;
        m.tokens_generated += n_tokens as u64;
        m.queue_s.push(timing.queue_s);
        m.ttft_s.push(timing.ttft_s);
        m.total_s.push(timing.total_s);
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().requests_failed += 1;
    }

    /// A mid-decode page-budget collision evicted a victim back to the
    /// queue (requeue, not failure).
    pub fn record_preemption(&self) {
        self.inner.lock().unwrap().preemptions += 1;
    }

    /// A page-budget collision was resolved by re-quantizing a victim's
    /// cold cache groups in place instead of evicting it (`bytes` freed
    /// back to the pool).
    pub fn record_downshift(&self, bytes: usize) {
        let mut m = self.inner.lock().unwrap();
        m.downshifts += 1;
        m.downshift_bytes_freed += bytes as u64;
    }

    /// A request was aborted by an explicit cancel (op or dropped
    /// connection). Counted separately from `requests_failed`: the work
    /// was abandoned, not broken.
    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    /// A request's `deadline_ms` expired (queued or mid-decode).
    pub fn record_deadline_expired(&self) {
        self.inner.lock().unwrap().deadline_expired += 1;
    }

    /// A tagged request entered flight (server-side registration).
    pub fn record_inflight_start(&self) {
        let mut m = self.inner.lock().unwrap();
        m.inflight_now += 1;
        m.inflight_peak = m.inflight_peak.max(m.inflight_now);
    }

    /// A tagged request's final frame was queued.
    pub fn record_inflight_end(&self) {
        let mut m = self.inner.lock().unwrap();
        m.inflight_now = m.inflight_now.saturating_sub(1);
    }

    pub fn record_prefill(&self, tokens: usize) {
        self.inner.lock().unwrap().prefill_tokens += tokens as u64;
    }

    /// One `batch_generate` submit of `items` work items.
    pub fn record_batch_submit(&self, items: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batch_requests += 1;
        m.batch_items += items as u64;
    }

    pub fn record_session_opened(&self) {
        self.inner.lock().unwrap().sessions_opened += 1;
    }

    pub fn record_session_closed(&self) {
        self.inner.lock().unwrap().sessions_closed += 1;
    }

    pub fn record_session_evicted(&self) {
        self.inner.lock().unwrap().sessions_evicted += 1;
    }

    pub fn record_decode_step(&self, batch: usize, dt_s: f64) {
        let mut m = self.inner.lock().unwrap();
        m.batch_sizes.push(batch as f64);
        m.decode_step_s.push(dt_s);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let elapsed = m.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsSnapshot {
            engine: Default::default(),
            elapsed_s: elapsed,
            requests_completed: m.requests_completed,
            requests_failed: m.requests_failed,
            preemptions: m.preemptions,
            downshifts: m.downshifts,
            downshift_bytes_freed: m.downshift_bytes_freed,
            cancelled: m.cancelled,
            deadline_expired: m.deadline_expired,
            inflight: m.inflight_now,
            inflight_peak: m.inflight_peak,
            tokens_generated: m.tokens_generated,
            prefill_tokens: m.prefill_tokens,
            batch_requests: m.batch_requests,
            batch_items: m.batch_items,
            sessions_opened: m.sessions_opened,
            sessions_closed: m.sessions_closed,
            sessions_evicted: m.sessions_evicted,
            throughput_tok_s: if elapsed > 0.0 {
                m.tokens_generated as f64 / elapsed
            } else {
                0.0
            },
            mean_batch: crate::util::stats::percentile(&m.batch_sizes, 50.0),
            queue_p50_s: percentile(&m.queue_s, 50.0),
            ttft_p50_s: percentile(&m.ttft_s, 50.0),
            ttft_p95_s: percentile(&m.ttft_s, 95.0),
            total_p50_s: percentile(&m.total_s, 50.0),
            total_p95_s: percentile(&m.total_s, 95.0),
            decode_step_p50_s: percentile(&m.decode_step_s, 50.0),
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Engine-level forward-path counters (decode fast path): host gather /
    /// literal-build / artifact-exec seconds, literal upload bytes and the
    /// staged-literal reuse split. Filled by `Coordinator::metrics` from
    /// `Engine::stats`; see docs/API.md `stats`.
    pub engine: crate::engine::EngineStats,
    pub elapsed_s: f64,
    pub requests_completed: u64,
    pub requests_failed: u64,
    /// Requests preempted (freed + requeued) on page-budget collisions.
    pub preemptions: u64,
    /// Page-budget collisions resolved by an in-place cache downshift
    /// (victim kept decoding at lower bits) instead of preemption.
    pub downshifts: u64,
    /// Pool bytes returned by those in-place downshifts.
    pub downshift_bytes_freed: u64,
    /// Requests aborted by an explicit cancel (op / dropped connection).
    pub cancelled: u64,
    /// Requests whose `deadline_ms` expired before completion.
    pub deadline_expired: u64,
    /// Tagged requests in flight right now (v3 multiplexing).
    pub inflight: u64,
    /// Peak concurrent tagged in-flight requests since start.
    pub inflight_peak: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub batch_requests: u64,
    pub batch_items: u64,
    pub sessions_opened: u64,
    pub sessions_closed: u64,
    pub sessions_evicted: u64,
    pub throughput_tok_s: f64,
    pub mean_batch: f64,
    pub queue_p50_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    pub total_p50_s: f64,
    pub total_p95_s: f64,
    pub decode_step_p50_s: f64,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("elapsed_s", Value::num(self.elapsed_s)),
            ("requests_completed", Value::num(self.requests_completed as f64)),
            ("requests_failed", Value::num(self.requests_failed as f64)),
            ("preemptions", Value::num(self.preemptions as f64)),
            ("downshifts", Value::num(self.downshifts as f64)),
            (
                "downshift_bytes_freed",
                Value::num(self.downshift_bytes_freed as f64),
            ),
            ("cancelled", Value::num(self.cancelled as f64)),
            ("deadline_expired", Value::num(self.deadline_expired as f64)),
            ("inflight", Value::num(self.inflight as f64)),
            ("inflight_peak", Value::num(self.inflight_peak as f64)),
            ("tokens_generated", Value::num(self.tokens_generated as f64)),
            ("prefill_tokens", Value::num(self.prefill_tokens as f64)),
            ("batch_requests", Value::num(self.batch_requests as f64)),
            ("batch_items", Value::num(self.batch_items as f64)),
            ("sessions_opened", Value::num(self.sessions_opened as f64)),
            ("sessions_closed", Value::num(self.sessions_closed as f64)),
            ("sessions_evicted", Value::num(self.sessions_evicted as f64)),
            ("throughput_tok_s", Value::num(self.throughput_tok_s)),
            ("mean_batch", Value::num(self.mean_batch)),
            ("queue_p50_s", Value::num(self.queue_p50_s)),
            ("ttft_p50_s", Value::num(self.ttft_p50_s)),
            ("ttft_p95_s", Value::num(self.ttft_p95_s)),
            ("total_p50_s", Value::num(self.total_p50_s)),
            ("total_p95_s", Value::num(self.total_p95_s)),
            ("decode_step_p50_s", Value::num(self.decode_step_p50_s)),
            // engine forward-path split (docs/API.md `stats`)
            ("gather_s", Value::num(self.engine.gather_s)),
            ("literal_build_s", Value::num(self.engine.literal_build_s)),
            ("exec_s", Value::num(self.engine.exec_s)),
            (
                "literal_bytes_built",
                Value::num(self.engine.literal_bytes_built as f64),
            ),
            ("lit_reused", Value::num(self.engine.lit_reused as f64)),
            ("lit_patched", Value::num(self.engine.lit_patched as f64)),
            ("lit_rebuilt", Value::num(self.engine.lit_rebuilt as f64)),
            ("engine_folds", Value::num(self.engine.folds as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Timing;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.start_clock();
        m.record_completion(
            &Timing { queue_s: 0.1, ttft_s: 0.2, total_s: 0.5, decode_steps: 3 },
            3,
        );
        m.record_completion(
            &Timing { queue_s: 0.3, ttft_s: 0.4, total_s: 0.7, decode_steps: 3 },
            3,
        );
        m.record_failure();
        m.record_preemption();
        m.record_downshift(4096);
        m.record_downshift(1024);
        m.record_cancelled();
        m.record_deadline_expired();
        m.record_inflight_start();
        m.record_inflight_start();
        m.record_inflight_end();
        m.record_inflight_start();
        m.record_decode_step(4, 0.01);
        m.record_batch_submit(3);
        m.record_session_opened();
        m.record_session_opened();
        m.record_session_closed();
        m.record_session_evicted();
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 2);
        assert_eq!(s.requests_failed, 1);
        assert_eq!(s.preemptions, 1);
        assert_eq!((s.downshifts, s.downshift_bytes_freed), (2, 5120));
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.deadline_expired, 1);
        assert_eq!((s.inflight, s.inflight_peak), (2, 2));
        assert_eq!(s.tokens_generated, 6);
        assert_eq!((s.batch_requests, s.batch_items), (1, 3));
        assert_eq!(s.sessions_opened, 2);
        assert_eq!(s.sessions_closed, 1);
        assert_eq!(s.sessions_evicted, 1);
        assert!((s.queue_p50_s - 0.2).abs() < 1e-9);
        assert!(s.throughput_tok_s > 0.0);
        let j = s.to_json();
        assert_eq!(j.get("requests_completed").as_i64(), Some(2));
    }
}
