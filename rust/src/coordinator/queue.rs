//! Admission queue: priority buckets with FIFO order inside each bucket,
//! plus policy-aware batch extraction (batches must be policy-homogeneous
//! because the layer artifacts are compiled per (k_bits, v_bits) variant).

use std::collections::{BTreeMap, VecDeque};

use super::request::InFlight;

#[derive(Default)]
pub struct RequestQueue {
    /// priority → FIFO; iterated highest priority first
    buckets: BTreeMap<i32, VecDeque<InFlight>>,
    len: usize,
}

impl RequestQueue {
    pub fn push(&mut self, inf: InFlight) {
        self.buckets
            .entry(inf.req.priority)
            .or_default()
            .push_back(inf);
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Peek the policy of the front-most (highest-priority, oldest) request.
    pub fn front_policy(&self) -> Option<&crate::quant::QuantPolicy> {
        self.buckets
            .iter()
            .rev()
            .find_map(|(_, q)| q.front())
            .map(|inf| &inf.req.policy)
    }

    /// Pop up to `max` requests whose policy NAME matches `policy_name`,
    /// scanning priority buckets from high to low but preserving FIFO order
    /// within a bucket (non-matching requests are left in place).
    pub fn pop_matching(&mut self, policy_name: &str, max: usize) -> Vec<InFlight> {
        let mut out = Vec::new();
        for (_, q) in self.buckets.iter_mut().rev() {
            let mut i = 0;
            while i < q.len() && out.len() < max {
                if q[i].req.policy.name == policy_name {
                    out.push(q.remove(i).unwrap());
                } else {
                    i += 1;
                }
            }
            if out.len() >= max {
                break;
            }
        }
        self.len -= out.len();
        out
    }

    /// Remove every queued request whose abort flag is set or whose
    /// deadline has passed `now` (regardless of priority bucket or policy
    /// — cancelled work must leave the queue even when the scheduler is
    /// busy with a different policy group). Returned entries have their
    /// abort kind latched; the scheduler fails them with typed errors.
    pub fn remove_aborted(
        &mut self,
        now: std::time::Instant,
    ) -> Vec<InFlight> {
        let mut out = Vec::new();
        for (_, q) in self.buckets.iter_mut() {
            let mut i = 0;
            while i < q.len() {
                if q[i].abort_status(now).is_some() {
                    out.push(q.remove(i).unwrap());
                } else {
                    i += 1;
                }
            }
        }
        self.len -= out.len();
        out
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Vec<InFlight> {
        let mut out = Vec::new();
        for (_, q) in self.buckets.iter_mut().rev() {
            out.extend(q.drain(..));
        }
        self.len = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Request, ResponseHandle};
    use crate::quant::QuantPolicy;

    fn inf(id: u64, prio: i32, policy: QuantPolicy) -> InFlight {
        let mut r = Request::greedy(id, vec![1], 1, policy);
        r.priority = prio;
        InFlight::new(r, ResponseHandle::new())
    }

    #[test]
    fn priority_then_fifo() {
        let mut q = RequestQueue::default();
        let p = QuantPolicy::float32(2);
        q.push(inf(1, 0, p.clone()));
        q.push(inf(2, 5, p.clone()));
        q.push(inf(3, 0, p.clone()));
        let got = q.pop_matching("float", 10);
        let ids: Vec<u64> = got.iter().map(|i| i.req.id).collect();
        assert_eq!(ids, vec![2, 1, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn policy_filtering_leaves_others() {
        let mut q = RequestQueue::default();
        q.push(inf(1, 0, QuantPolicy::float32(2)));
        q.push(inf(2, 0, QuantPolicy::kivi(2, 2)));
        q.push(inf(3, 0, QuantPolicy::float32(2)));
        let got = q.pop_matching("float", 10);
        assert_eq!(got.len(), 2);
        assert_eq!(q.len(), 1);
        assert_eq!(q.front_policy().unwrap().name, "KIVI-2bit");
    }

    #[test]
    fn remove_aborted_sweeps_all_buckets() {
        let mut q = RequestQueue::default();
        let p = QuantPolicy::float32(2);
        let a = inf(1, 0, p.clone());
        let b = inf(2, 5, p.clone());
        let c = inf(3, 5, p.clone());
        b.req.abort.cancel();
        q.push(a);
        q.push(b);
        q.push(c);
        // a deadline in the past expires regardless of bucket
        let mut d = inf(4, -3, p.clone());
        d.req.deadline =
            Some(std::time::Instant::now() - std::time::Duration::from_millis(1));
        q.push(d);
        let aborted = q.remove_aborted(std::time::Instant::now());
        let ids: Vec<u64> = aborted.iter().map(|i| i.req.id).collect();
        assert_eq!(aborted.len(), 2, "{ids:?}");
        assert!(ids.contains(&2) && ids.contains(&4));
        assert_eq!(q.len(), 2);
        // survivors still pop normally
        let got = q.pop_matching("float", 10);
        let ids: Vec<u64> = got.iter().map(|i| i.req.id).collect();
        assert_eq!(ids, vec![3, 1]);
    }

    #[test]
    fn max_respected() {
        let mut q = RequestQueue::default();
        let p = QuantPolicy::float32(2);
        for i in 0..5 {
            q.push(inf(i, 0, p.clone()));
        }
        let got = q.pop_matching("float", 2);
        assert_eq!(got.len(), 2);
        assert_eq!(q.len(), 3);
    }
}
