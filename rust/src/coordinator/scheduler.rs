//! The continuous-batching scheduler: admits requests from the priority
//! queue (policy-homogeneous prefill batches) under an expected-pages
//! estimate, interleaves one decode step per iteration across all active
//! sequences (grouped by policy, since the layer artifacts are compiled
//! per bit-variant), retires finished requests and applies cache-pool
//! backpressure.
//!
//! The pool is demand-paged (see `kvcache/pool.rs`), so admission is
//! optimistic: a request is admitted when its *projected* footprint
//! (prompt + n_gen, page-rounded) fits next to the currently resident
//! pages. Previously admitted sequences keep growing, so concurrent
//! long generations can collide mid-decode; the engine then bounces the
//! step with `BudgetExceeded` BEFORE touching any cache, and the
//! scheduler first tries a **downshift** — re-quantizing one victim's
//! already-folded cache groups in place at the next lower grid-supported
//! bit-width (`LayerCache::downshift_groups`), which frees pages while
//! every sequence keeps decoding — and only **preempts** when nobody can
//! shift down: the lowest-priority, youngest non-session request is freed
//! and requeued (its retry re-prefills with a reset RNG, reproducing the
//! uninterrupted output) instead of anything panicking or failing. All
//! waiting is notification-driven: the queue condvar covers submissions
//! and shutdown, and the pool's free-epoch condvar covers capacity
//! releases, so the scheduler never sleep-polls.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::{sample, Engine};
use crate::kvcache::PoolError;
use crate::quant::{Bits, QuantPolicy};

use super::metrics::Metrics;
use super::queue::RequestQueue;
use super::request::{AbortKind, InFlight, Response, Timing};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// cap on concurrently active sequences (admission control)
    pub max_active: usize,
    /// cap on sequences stepped per decode call per policy group
    pub max_batch: usize,
    /// linger before prefilling a lone arrival, to give the batcher a
    /// chance to group requests (ablated in the perf bench); skipped when
    /// the queue already holds a full batch or shutdown is flagged
    pub batch_window: Duration,
    /// byte budget for the KV prefix cache (0 disables prefix reuse)
    pub prefix_cache_bytes: usize,
    /// On a mid-decode page-budget collision, try re-quantizing one
    /// victim's cold cache groups in place (freeing pages, keeping every
    /// sequence running at reduced precision) before falling back to
    /// preemption. Disable to pin the strict evict-and-replay behaviour,
    /// whose retries reproduce the uncontended output byte-for-byte.
    pub downshift: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_active: 16,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            prefix_cache_bytes: 0,
            downshift: true,
        }
    }
}

/// Backstop for the pool-capacity wait: releases and shutdown notify the
/// condvar (and bump the free epoch), so this only bounds the damage of a
/// hypothetical missed signal — it is not a poll interval.
const CAPACITY_WAIT_BACKSTOP: Duration = Duration::from_millis(250);

pub(super) struct Shared {
    pub engine: Arc<Engine>,
    pub queue: Mutex<RequestQueue>,
    pub cv: Condvar,
    pub shutdown: AtomicBool,
    pub metrics: Metrics,
    pub cfg: CoordinatorConfig,
    pub prefix_cache: Option<crate::kvcache::PrefixCache>,
}

pub(super) fn run_scheduler(shared: Arc<Shared>) {
    let mut active: Vec<InFlight> = Vec::new();
    loop {
        // ---- wait for work (notification-driven: submit() and shutdown()
        // both signal the queue condvar, so no timeout is needed) ----
        if active.is_empty() {
            let mut q = shared.queue.lock().unwrap();
            while q.is_empty() && !shared.shutdown.load(Ordering::SeqCst) {
                q = shared.cv.wait(q).unwrap();
            }
            if q.is_empty() && shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let backlog = q.len();
            drop(q);
            // batching window: let near-simultaneous arrivals pile up —
            // pointless when a full batch is already queued or we are
            // shutting down
            if !shared.cfg.batch_window.is_zero()
                && backlog < shared.cfg.max_batch
                && !shared.shutdown.load(Ordering::SeqCst)
            {
                std::thread::sleep(shared.cfg.batch_window);
            }
        }

        // ---- abort sweep (cancel / deadline expiry) ----
        // Runs once per scheduler iteration, i.e. at decode-step
        // granularity: cancelled/expired queued requests leave the queue
        // wherever they sit (any bucket, any policy), and aborted ACTIVE
        // requests are retired before the next decode step — freeing
        // their pool pages immediately so waiting admissions unblock.
        sweep_aborted(&shared, &mut active);

        // Capture the pool's free epoch BEFORE attempting admission: a
        // release between a bounce below and the capacity wait would
        // otherwise be lost and cost a full backstop interval.
        let pool_epoch = shared.engine.pool.free_epoch();

        // ---- admit + prefill (policy-homogeneous groups) ----
        loop {
            let group = {
                let mut q = shared.queue.lock().unwrap();
                let free = shared.cfg.max_active.saturating_sub(active.len());
                if free == 0 || q.is_empty() {
                    Vec::new()
                } else {
                    let pname = q.front_policy().unwrap().name.clone();
                    q.pop_matching(&pname, free)
                }
            };
            if group.is_empty() {
                break;
            }
            let (mut admitted, requeue) = prefill_group(&shared, group);
            let blocked = !requeue.is_empty();
            if blocked {
                let mut q = shared.queue.lock().unwrap();
                for inf in requeue {
                    q.push(inf);
                }
            }
            let made_progress = !admitted.is_empty();
            active.append(&mut admitted);
            if blocked || !made_progress {
                break; // backpressure: stop admitting this round
            }
        }

        // nothing running but work is queued (all bounced by backpressure):
        // block until the pool actually releases capacity
        if active.is_empty() {
            if shared.shutdown.load(Ordering::SeqCst) {
                // shutting down and nothing can be admitted: fail the rest
                for mut inf in shared.queue.lock().unwrap().drain() {
                    fail(&shared, &mut inf, "shutdown with backpressure");
                }
                return;
            }
            // the abort sweep may have emptied the queue (everything
            // pending was cancelled): go straight back to the idle wait
            // instead of burning a capacity-backstop interval
            if shared.queue.lock().unwrap().is_empty() {
                continue;
            }
            shared
                .engine
                .pool
                .wait_for_free(pool_epoch, CAPACITY_WAIT_BACKSTOP);
            continue;
        }

        // ---- one decode step per policy group ----
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, inf) in active.iter().enumerate() {
            match groups.iter_mut().find(|g| {
                active[g[0]].req.policy.name == inf.req.policy.name
                    && g.len() < shared.cfg.max_batch
            }) {
                Some(g) => g.push(i),
                None => groups.push(vec![i]),
            }
        }
        'groups: for group in groups {
            let ids: Vec<u64> =
                group.iter().map(|&i| active[i].seq_id.unwrap()).collect();
            let toks: Vec<i32> =
                group.iter().map(|&i| active[i].cur_token.unwrap()).collect();
            let t0 = Instant::now();
            match shared.engine.decode(&ids, &toks) {
                Ok(logits) => {
                    shared
                        .metrics
                        .record_decode_step(ids.len(), t0.elapsed().as_secs_f64());
                    for (&i, l) in group.iter().zip(&logits) {
                        let inf = &mut active[i];
                        let tok = sample(l, &inf.req.sampling, &mut inf.rng);
                        let emitted = inf.cur_token.unwrap();
                        inf.generated.push(emitted);
                        if let Some(sink) = &inf.req.on_token {
                            sink(inf.req.id, emitted);
                        }
                        inf.cur_token = Some(tok);
                    }
                }
                Err(e) => {
                    // A page-budget bounce happens BEFORE any cache
                    // mutation (the engine reserves first), so every
                    // sequence is intact. First choice: downshift a
                    // victim's cold cache groups in place — pages come
                    // back without evicting anyone. Otherwise preempt a
                    // victim back to the queue and retry the survivors
                    // next iteration. When no victim is requeue-eligible
                    // (sessions, streams), shed ONE member of the
                    // colliding group — the rest are untouched and retry
                    // — rather than failing the whole batch.
                    let budget = matches!(
                        e.downcast_ref::<PoolError>(),
                        Some(PoolError::BudgetExceeded { .. })
                    );
                    if budget {
                        if shared.cfg.downshift && downshift_one(&shared, &mut active)
                        {
                            break 'groups;
                        }
                        if !preempt_one(&shared, &mut active) {
                            let victim = group
                                .iter()
                                .copied()
                                .filter(|&i| !active[i].handle.is_fulfilled())
                                .min_by_key(|&i| {
                                    (
                                        active[i].req.priority,
                                        std::cmp::Reverse(active[i].submitted),
                                    )
                                });
                            if let Some(vi) = victim {
                                fail(
                                    &shared,
                                    &mut active[vi],
                                    "page budget exhausted with no preemptable victim",
                                );
                                active[vi].generated = vec![];
                            }
                        }
                        // indices into `active` may be stale after a
                        // preemption; rebuild groups next loop iteration
                        break 'groups;
                    }
                    for &i in &group {
                        fail(&shared, &mut active[i], &format!("decode failed: {e}"));
                        active[i].generated = vec![]; // mark failed via handle
                    }
                }
            }
        }

        // ---- retire ----
        let mut i = 0;
        while i < active.len() {
            let fulfilled = active[i].handle.is_fulfilled();
            if active[i].done() || fulfilled {
                let inf = active.swap_remove(i);
                if !fulfilled {
                    complete(&shared, inf);
                } else if let Some(id) = inf.seq_id {
                    if inf.req.session_seq.is_none() {
                        let _ = shared.engine.free_seq(id);
                    }
                }
            } else {
                i += 1;
            }
        }

        if shared.shutdown.load(Ordering::SeqCst)
            && active.is_empty()
            && shared.queue.lock().unwrap().is_empty()
        {
            return;
        }
    }
}

/// Retire cancelled / deadline-expired requests: queued ones leave the
/// queue (wherever they sit), active ones are removed and their sequence's
/// pool pages freed before the next decode step. Each gets a typed
/// `cancelled` / `deadline_exceeded` response. Requests whose handle is
/// already fulfilled are left for the ordinary retire loop.
fn sweep_aborted(shared: &Arc<Shared>, active: &mut Vec<InFlight>) {
    let now = Instant::now();
    let aborted_queued = shared.queue.lock().unwrap().remove_aborted(now);
    for mut inf in aborted_queued {
        // recompute with the same `now` the removal used: a deadline that
        // was past then is still past (the fallback is unreachable)
        let kind = inf.abort_status(now).unwrap_or(AbortKind::Cancelled);
        fail_aborted(shared, &mut inf, kind);
    }
    let mut i = 0;
    while i < active.len() {
        match active[i].abort_status(now) {
            Some(kind) if !active[i].handle.is_fulfilled() => {
                let mut inf = active.swap_remove(i);
                fail_aborted(shared, &mut inf, kind);
            }
            _ => i += 1,
        }
    }
}

/// Bit-width rungs in decreasing footprint order; 0 (fp32) sits on top.
const BIT_LADDER: [Bits; 5] = [0, 8, 4, 2, 1];

/// Every rung strictly below `b` on the footprint ladder, widest first.
fn lower_rungs(b: Bits) -> &'static [Bits] {
    let pos = BIT_LADDER
        .iter()
        .position(|&x| x == b)
        .unwrap_or(BIT_LADDER.len() - 1);
    &BIT_LADDER[pos + 1..]
}

/// The gentlest downshift of one layer's `(k, v)` pair that the model's
/// lowered artifact grid actually supports: prefer dropping both sides
/// (to the widest usable rungs), then K alone, then V alone. Returns
/// `None` when the pair is already at the grid's floor.
fn step_down_pair(k: Bits, v: Bits, grid: &[(u8, u8)]) -> Option<(Bits, Bits)> {
    for &nk in lower_rungs(k) {
        for &nv in lower_rungs(v) {
            if grid.contains(&(nk, nv)) {
                return Some((nk, nv));
            }
        }
    }
    for &nk in lower_rungs(k) {
        if grid.contains(&(nk, v)) {
            return Some((nk, v));
        }
    }
    for &nv in lower_rungs(v) {
        if grid.contains(&(k, nv)) {
            return Some((k, nv));
        }
    }
    None
}

/// Relieve a page-budget collision WITHOUT evicting anyone: pick one
/// victim (lowest priority, then youngest — the same ordering as
/// [`preempt_one`]) and re-quantize its already-folded cache groups in
/// place one grid-supported bit rung down
/// (`LayerCache::downshift_groups`). The shrink settles through the
/// pool, so the freed pages are visible to the retried decode step
/// immediately. Sessions are excluded — a session's policy is fixed when
/// it opens and later turns must keep resolving the same artifacts — but
/// streams ARE eligible: nothing is evicted, so no emitted token is ever
/// replayed. Unlike preemption this also works with a single active
/// sequence (it shrinks itself out of its own collision). Returns false
/// when no candidate has a lower rung to move to or the chosen victim
/// held nothing cold enough to shrink; the caller then falls back to
/// preemption.
fn downshift_one(shared: &Arc<Shared>, active: &mut [InFlight]) -> bool {
    let grid = &shared.engine.manifest().grid;
    let mut victim: Option<usize> = None;
    for (i, inf) in active.iter().enumerate() {
        if inf.seq_id.is_none()
            || inf.req.session_seq.is_some()
            || inf.handle.is_fulfilled()
        {
            continue;
        }
        let p = &inf.req.policy;
        // eligible = some layer has both a lower grid rung to move to AND
        // cold (already-folded) tokens whose repack returns real pages —
        // without cold data a downshift would spend the victim's rung for
        // nothing, so such candidates are left to the preemption fallback
        let eligible = shared
            .engine
            .pool
            .with_seq(inf.seq_id.unwrap(), |s| {
                // attached sequences are excluded: their packed region
                // aliases an immutable shared base that an in-place
                // repack must never rewrite (downshift_groups asserts
                // the sequence owns its pages)
                s.base.is_none()
                    && s.layers
                        .iter()
                        .zip(p.k_bits.iter().zip(&p.v_bits))
                        .any(|(l, (&k, &v))| {
                            l.n_tokens() > l.n_res()
                                && step_down_pair(k, v, grid).is_some()
                        })
            })
            .unwrap_or(false);
        if !eligible {
            continue;
        }
        victim = match victim {
            None => Some(i),
            Some(v) => {
                let lower = inf.req.priority < active[v].req.priority
                    || (inf.req.priority == active[v].req.priority
                        && inf.submitted > active[v].submitted);
                if lower { Some(i) } else { Some(v) }
            }
        };
    }
    let Some(vi) = victim else { return false };
    let inf = &mut active[vi];
    let seq_id = inf.seq_id.unwrap();
    let mut new_k = inf.req.policy.k_bits.clone();
    let mut new_v = inf.req.policy.v_bits.clone();
    let mut plan: Vec<(usize, Bits, Bits)> = Vec::new();
    for l in 0..new_k.len() {
        if let Some((nk, nv)) = step_down_pair(new_k[l], new_v[l], grid) {
            plan.push((l, nk, nv));
            new_k[l] = nk;
            new_v[l] = nv;
        }
    }
    let Ok(freed) = shared.engine.pool.with_seq(seq_id, |s| {
        plan.iter()
            .map(|&(l, nk, nv)| s.layers[l].downshift_groups(nk, nv))
            .sum::<usize>()
    }) else {
        return false;
    };
    // the cache's bit-widths changed even if nothing was resident to
    // repack, so the request's policy must follow: decode regrouping and
    // the engine's artifact selection both key on the live bits
    inf.req.policy = QuantPolicy::asymkv_auto(new_k, new_v);
    if freed == 0 {
        return false;
    }
    shared.metrics.record_downshift(freed);
    true
}

/// Evict one active request back to the queue to relieve a page-budget
/// collision: the lowest-priority, youngest non-session, non-streaming
/// request (sessions hold pinned state that must not be freed; a stream
/// has already emitted tokens that a retry would duplicate). Returns
/// false when no eligible victim exists (the caller then fails the
/// stalled group instead — with a single active request a self-preempt
/// would just retry into the same wall).
fn preempt_one(shared: &Arc<Shared>, active: &mut Vec<InFlight>) -> bool {
    if active.len() <= 1 {
        return false;
    }
    let mut victim: Option<usize> = None;
    for (i, inf) in active.iter().enumerate() {
        if inf.req.session_seq.is_some()
            || inf.req.on_token.is_some()
            || inf.handle.is_fulfilled()
        {
            continue;
        }
        victim = match victim {
            None => Some(i),
            Some(v) => {
                let lower = inf.req.priority < active[v].req.priority
                    || (inf.req.priority == active[v].req.priority
                        && inf.submitted > active[v].submitted);
                if lower { Some(i) } else { Some(v) }
            }
        };
    }
    let Some(i) = victim else { return false };
    let mut inf = active.swap_remove(i);
    if let Some(id) = inf.seq_id.take() {
        let _ = shared.engine.free_seq(id); // wakes capacity waiters
    }
    inf.reset_for_retry();
    shared.metrics.record_preemption();
    shared.queue.lock().unwrap().push(inf);
    true
}

/// Prefill a policy-homogeneous group. Returns `(active, requeue)`: requests
/// that were admitted + prefilled, and requests bounced by pool
/// backpressure (to be requeued by the caller).
fn prefill_group(
    shared: &Arc<Shared>,
    group: Vec<InFlight>,
) -> (Vec<InFlight>, Vec<InFlight>) {
    // allocate sequences; on budget exhaustion, requeue the tail
    let mut admitted: Vec<InFlight> = Vec::new();
    let mut requeue: Vec<InFlight> = Vec::new();
    for mut inf in group {
        // a cancel/deadline can land between the sweep and this pop —
        // don't spend a prefill on work nobody wants
        if let Some(kind) = inf.abort_status(Instant::now()) {
            fail_aborted(shared, &mut inf, kind);
            continue;
        }
        if !requeue.is_empty() {
            requeue.push(inf); // preserve order behind the first bounce
            continue;
        }
        // Context-budget admission check for EVERY request: a request
        // appends prompt + n_gen tokens (prefill + one per decode step)
        // and the engine has no decode-time bound — admitting an
        // over-budget request would stall the scheduler on "quantized
        // region full" mid-decode. Sessions make this routine (history
        // accumulates across turns); huge n_gen makes it reachable even
        // on a fresh sequence.
        let held = match inf.req.session_seq {
            Some(id) => match shared.engine.seq_pos(id) {
                Ok(pos) => pos,
                Err(_) => {
                    fail(shared, &mut inf, &format!("unknown session sequence {id}"));
                    continue;
                }
            },
            // a prefix-attached request starts at the shared node's
            // position: its resident prefix counts against the context
            // budget exactly like retained session history does
            None => inf.req.prefix.as_ref().map_or(0, |e| e.base.pos),
        };
        let m = shared.engine.manifest();
        // max(1) keeps this at least as strict as the engine's own
        // prefill check (held + len + 1), which bails whole batches
        let need = inf.req.prompt.len() + inf.req.n_gen.max(1);
        if held + need > m.max_ctx + m.residual {
            fail(
                shared,
                &mut inf,
                &format!(
                    "context budget exhausted: {held} held + {need} for this \
                     request exceed T={} R={}",
                    m.max_ctx, m.residual
                ),
            );
            continue;
        }
        // Expected-pages admission (demand-paged pool): allocation alone
        // charges almost nothing, so gate on the page-rounded footprint
        // this request will grow to. Optimistic — already-active
        // sequences keep growing too; mid-decode collisions preempt.
        let verdict = match (inf.req.session_seq, &inf.req.prefix) {
            (Some(id), _) => shared.engine.pool.admit_growth(id, need),
            // attached sequences are charged NET of the shared node:
            // only the private tail, plus the node's bytes when (and
            // only when) it is not already resident
            (None, Some(entry)) => {
                shared.engine.pool.admit_attached(&entry.base, need)
            }
            (None, None) => shared.engine.pool.admit(&inf.req.policy, need),
        };
        if let Err(e) = verdict {
            // A bounce is transient only if waiting can EVER free enough:
            // a session's own resident pages are pinned and will never be
            // reclaimed by waiting, so they count against the budget the
            // growth must fit into (otherwise a grown session's next turn
            // would requeue forever and hang its client).
            let own = inf
                .req
                .session_seq
                .and_then(|id| shared.engine.seq_bytes(id).ok())
                .unwrap_or(0);
            match e {
                // transient: waiting will free capacity
                PoolError::BudgetExceeded { requested, budget, .. }
                    if requested + own <= budget =>
                {
                    requeue.push(inf);
                }
                // permanent: this request can never fit — fail it (for a
                // session turn this also evicts the session, releasing
                // its pinned pages)
                _ => fail(shared, &mut inf, &format!("admission failed: {e}")),
            }
            continue;
        }
        // session turns ride on a pre-allocated pinned sequence: no
        // allocation and never freed by the scheduler
        if let Some(id) = inf.req.session_seq {
            inf.seq_id = Some(id);
            inf.admitted_at = Some(Instant::now());
            admitted.push(inf);
            continue;
        }
        let created = match &inf.req.prefix {
            // prefix_id fast path: the sequence ATTACHES the shared node
            // read-only (zero bytes copied) instead of starting empty
            Some(entry) => shared.engine.create_seq_attached(&entry.base),
            None => shared.engine.create_seq(&inf.req.policy),
        };
        match created {
            Ok(id) => {
                inf.seq_id = Some(id);
                inf.admitted_at = Some(Instant::now());
                admitted.push(inf);
            }
            Err(e) => {
                match e.downcast_ref::<PoolError>() {
                    // transient: waiting will free capacity
                    Some(PoolError::BudgetExceeded { requested, budget, .. })
                        if requested <= budget =>
                    {
                        requeue.push(inf);
                    }
                    // permanent: this request can never fit — fail it
                    _ => fail(shared, &mut inf, &format!("admission failed: {e}")),
                }
            }
        }
    }
    if admitted.is_empty() {
        return (Vec::new(), requeue);
    }

    // Session turns AND prefix-attached requests are isolated from
    // ordinary requests: (a) the prefix cache must never see them — a
    // turn's (or attached request's) prompt is only the delta text, so a
    // restore would clobber the retained KV state and a snapshot would
    // file the suffix under the wrong key — and (b) the engine fails a
    // prefill batch as a whole, so one oversized ordinary prompt must not
    // sink (and thereby evict) an innocent session. Mixed groups
    // therefore always prefill in two engine calls, cache or no cache.
    // Session-vs-session interference within the isolated half is
    // pre-empted by the context check at admission above.
    let isolated =
        |i: &InFlight| i.req.session_seq.is_some() || i.req.prefix.is_some();
    let any_iso = admitted.iter().any(isolated);
    let all_iso = admitted.iter().all(isolated);
    if any_iso && !all_iso {
        let (iso_group, other_group): (Vec<InFlight>, Vec<InFlight>) =
            admitted.into_iter().partition(isolated);
        let (mut done, mut bounced) = prefill_subset(shared, iso_group, false);
        let (done2, bounced2) = prefill_subset(shared, other_group, true);
        done.extend(done2);
        bounced.extend(bounced2);
        requeue.extend(bounced);
        return (done, requeue);
    }
    let use_cache = !any_iso;
    let (done, bounced) = prefill_subset(shared, admitted, use_cache);
    requeue.extend(bounced);
    (done, requeue)
}

/// Prefill one policy-homogeneous group with a single engine call,
/// assigning each request its first token. A page-budget bounce (raised by
/// the engine's reservation BEFORE any cache mutation) sheds the group's
/// tail member back to the queue and retries the rest — bounded by the
/// group size, and guaranteed to make progress whenever any single
/// member's prompt fits. On any other engine error only THIS group's
/// requests are failed. Returns `(survivors, bounced)`.
fn prefill_subset(
    shared: &Arc<Shared>,
    group: Vec<InFlight>,
    use_cache: bool,
) -> (Vec<InFlight>, Vec<InFlight>) {
    // Prefix fast path: an attached request with an EMPTY suffix skips
    // prefill entirely — its first token samples straight from the shared
    // node's stored last-position logits (the prefix_id TTFT win: no
    // prompt bytes re-sent, no prefill pass re-run). The engine rejects
    // empty prompts, so these must never reach the batched call below.
    let mut ready: Vec<InFlight> = Vec::new();
    let mut rest: Vec<InFlight> = Vec::new();
    for mut inf in group {
        match inf.req.prefix.clone() {
            Some(entry) if inf.req.prompt.is_empty() => {
                let tok =
                    sample(&entry.last_logits, &inf.req.sampling, &mut inf.rng);
                inf.cur_token = Some(tok);
                inf.first_token_at = Some(Instant::now());
                ready.push(inf);
            }
            _ => rest.push(inf),
        }
    }
    let mut group = rest;
    let mut bounced: Vec<InFlight> = Vec::new();
    loop {
        if group.is_empty() {
            return (ready, bounced);
        }
        let ids: Vec<u64> = group.iter().map(|i| i.seq_id.unwrap()).collect();
        let prompts: Vec<Vec<i32>> =
            group.iter().map(|i| i.req.prompt.clone()).collect();
        let n_prompt: usize = prompts.iter().map(|p| p.len()).sum();
        // both branches yield Arc-shared logits: `prefill_cached` hands
        // out the stored Arc on exact hits, the plain path wraps its own
        let result = match &shared.prefix_cache {
            Some(pc) if use_cache => shared.engine.prefill_cached(&ids, &prompts, pc),
            _ => shared
                .engine
                .prefill(&ids, &prompts)
                .map(|ls| ls.into_iter().map(std::sync::Arc::new).collect()),
        };
        match result {
            Ok(logits) => {
                shared.metrics.record_prefill(n_prompt);
                let now = Instant::now();
                for (inf, l) in group.iter_mut().zip(&logits) {
                    let tok = sample(l, &inf.req.sampling, &mut inf.rng);
                    inf.cur_token = Some(tok);
                    inf.first_token_at = Some(now);
                }
                ready.extend(group);
                return (ready, bounced);
            }
            Err(e) => {
                if matches!(
                    e.downcast_ref::<PoolError>(),
                    Some(PoolError::BudgetExceeded { .. })
                ) {
                    // the reservation bounced before any prompt token
                    // became resident: shed the youngest member (release
                    // its sequence, requeue) and retry the smaller group
                    let mut inf = group.pop().unwrap();
                    if inf.req.session_seq.is_none() {
                        if let Some(id) = inf.seq_id.take() {
                            let _ = shared.engine.free_seq(id);
                        }
                    }
                    inf.reset_for_retry();
                    bounced.push(inf);
                } else {
                    for mut inf in group {
                        fail(shared, &mut inf, &format!("prefill failed: {e}"));
                    }
                    return (ready, bounced);
                }
            }
        }
    }
}

fn complete(shared: &Arc<Shared>, inf: InFlight) {
    let total = inf.submitted.elapsed().as_secs_f64();
    let ttft = inf
        .first_token_at
        .map(|t| t.duration_since(inf.submitted).as_secs_f64())
        .unwrap_or(total);
    // queue wait ends at (the final) admission; TTFT additionally includes
    // prefill, so the two are separable in metrics (docs/API.md)
    let queue_s = inf
        .admitted_at
        .map(|t| t.duration_since(inf.submitted).as_secs_f64())
        .unwrap_or(ttft);
    let timing = Timing {
        queue_s,
        ttft_s: ttft,
        total_s: total,
        decode_steps: inf.generated.len(),
    };
    shared.metrics.record_completion(&timing, inf.generated.len());
    if let Some(id) = inf.seq_id {
        // session sequences outlive the request (freed by session close)
        if inf.req.session_seq.is_none() {
            let _ = shared.engine.free_seq(id);
        }
    }
    inf.handle.fulfill(Response {
        id: inf.req.id,
        tokens: inf.generated.clone(),
        timing,
        error: None,
        abort: None,
    });
}

fn fail(shared: &Arc<Shared>, inf: &mut InFlight, msg: &str) {
    shared.metrics.record_failure();
    finish_failed(shared, inf, msg, None);
}

/// Typed abort completion: counts into the `cancelled` /
/// `deadline_expired` metrics (NOT `requests_failed` — the work was
/// abandoned or timed out, not broken) and carries the kind so the API
/// layer emits the matching wire error code.
fn fail_aborted(shared: &Arc<Shared>, inf: &mut InFlight, kind: AbortKind) {
    let msg = match kind {
        AbortKind::Cancelled => {
            shared.metrics.record_cancelled();
            "request cancelled"
        }
        AbortKind::DeadlineExceeded => {
            shared.metrics.record_deadline_expired();
            "deadline exceeded"
        }
    };
    finish_failed(shared, inf, msg, Some(kind));
}

fn finish_failed(
    shared: &Arc<Shared>,
    inf: &mut InFlight,
    msg: &str,
    abort: Option<AbortKind>,
) {
    if let Some(id) = inf.seq_id.take() {
        // session sequences stay pinned: a failed/cancelled turn is the
        // session manager's cue to evict (which releases the pages)
        if inf.req.session_seq.is_none() {
            let _ = shared.engine.free_seq(id);
        }
    }
    inf.handle.fulfill(Response {
        id: inf.req.id,
        tokens: inf.generated.clone(),
        timing: Timing::default(),
        error: Some(msg.to_string()),
        abort,
    });
}
