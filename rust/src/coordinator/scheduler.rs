//! The continuous-batching scheduler: admits requests from the priority
//! queue (policy-homogeneous prefill batches), interleaves one decode step
//! per iteration across all active sequences (grouped by policy, since the
//! layer artifacts are compiled per bit-variant), retires finished requests
//! and applies cache-pool backpressure.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::{sample, Engine};
use crate::kvcache::PoolError;

use super::metrics::Metrics;
use super::queue::RequestQueue;
use super::request::{InFlight, Response, Timing};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// cap on concurrently active sequences (admission control)
    pub max_active: usize,
    /// cap on sequences stepped per decode call per policy group
    pub max_batch: usize,
    /// linger before prefilling a lone arrival, to give the batcher a
    /// chance to group requests (ablated in the perf bench)
    pub batch_window: Duration,
    /// byte budget for the KV prefix cache (0 disables prefix reuse)
    pub prefix_cache_bytes: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_active: 16,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            prefix_cache_bytes: 0,
        }
    }
}

pub(super) struct Shared {
    pub engine: Arc<Engine>,
    pub queue: Mutex<RequestQueue>,
    pub cv: Condvar,
    pub shutdown: AtomicBool,
    pub metrics: Metrics,
    pub cfg: CoordinatorConfig,
    pub prefix_cache: Option<crate::kvcache::PrefixCache>,
}

pub(super) fn run_scheduler(shared: Arc<Shared>) {
    let mut active: Vec<InFlight> = Vec::new();
    loop {
        // ---- wait for work ----
        if active.is_empty() {
            let mut q = shared.queue.lock().unwrap();
            while q.is_empty() && !shared.shutdown.load(Ordering::SeqCst) {
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
            if q.is_empty() && shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            drop(q);
            // batching window: let near-simultaneous arrivals pile up
            if !shared.cfg.batch_window.is_zero() {
                std::thread::sleep(shared.cfg.batch_window);
            }
        }

        // ---- admit + prefill (policy-homogeneous groups) ----
        loop {
            let group = {
                let mut q = shared.queue.lock().unwrap();
                let free = shared.cfg.max_active.saturating_sub(active.len());
                if free == 0 || q.is_empty() {
                    Vec::new()
                } else {
                    let pname = q.front_policy().unwrap().name.clone();
                    q.pop_matching(&pname, free)
                }
            };
            if group.is_empty() {
                break;
            }
            let (mut admitted, requeue) = prefill_group(&shared, group);
            let blocked = !requeue.is_empty();
            if blocked {
                let mut q = shared.queue.lock().unwrap();
                for inf in requeue {
                    q.push(inf);
                }
            }
            let made_progress = !admitted.is_empty();
            active.append(&mut admitted);
            if blocked || !made_progress {
                break; // backpressure: stop admitting this round
            }
        }

        // nothing running but work is queued (all bounced by backpressure):
        // don't busy-spin against the pool
        if active.is_empty() {
            if shared.shutdown.load(Ordering::SeqCst) {
                // shutting down and nothing can be admitted: fail the rest
                for mut inf in shared.queue.lock().unwrap().drain() {
                    fail(&shared, &mut inf, "shutdown with backpressure");
                }
                return;
            }
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }

        // ---- one decode step per policy group ----
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, inf) in active.iter().enumerate() {
            match groups.iter_mut().find(|g| {
                active[g[0]].req.policy.name == inf.req.policy.name
                    && g.len() < shared.cfg.max_batch
            }) {
                Some(g) => g.push(i),
                None => groups.push(vec![i]),
            }
        }
        for group in groups {
            let ids: Vec<u64> =
                group.iter().map(|&i| active[i].seq_id.unwrap()).collect();
            let toks: Vec<i32> =
                group.iter().map(|&i| active[i].cur_token.unwrap()).collect();
            let t0 = Instant::now();
            match shared.engine.decode(&ids, &toks) {
                Ok(logits) => {
                    shared
                        .metrics
                        .record_decode_step(ids.len(), t0.elapsed().as_secs_f64());
                    for (&i, l) in group.iter().zip(&logits) {
                        let inf = &mut active[i];
                        let tok = sample(l, &inf.req.sampling, &mut inf.rng);
                        let emitted = inf.cur_token.unwrap();
                        inf.generated.push(emitted);
                        if let Some(sink) = &inf.req.on_token {
                            sink(inf.req.id, emitted);
                        }
                        inf.cur_token = Some(tok);
                    }
                }
                Err(e) => {
                    for &i in &group {
                        fail(&shared, &mut active[i], &format!("decode failed: {e}"));
                        active[i].generated = vec![]; // mark failed via handle
                    }
                }
            }
        }

        // ---- retire ----
        let mut i = 0;
        while i < active.len() {
            if active[i].done() || active[i].handle.try_get().is_some() {
                let inf = active.swap_remove(i);
                if inf.handle.try_get().is_none() {
                    complete(&shared, inf);
                } else if let Some(id) = inf.seq_id {
                    if inf.req.session_seq.is_none() {
                        let _ = shared.engine.free_seq(id);
                    }
                }
            } else {
                i += 1;
            }
        }

        if shared.shutdown.load(Ordering::SeqCst)
            && active.is_empty()
            && shared.queue.lock().unwrap().is_empty()
        {
            return;
        }
    }
}

/// Prefill a policy-homogeneous group. Returns `(active, requeue)`: requests
/// that were admitted + prefilled, and requests bounced by pool
/// backpressure (to be requeued by the caller).
fn prefill_group(
    shared: &Arc<Shared>,
    group: Vec<InFlight>,
) -> (Vec<InFlight>, Vec<InFlight>) {
    // allocate sequences; on budget exhaustion, requeue the tail
    let mut admitted: Vec<InFlight> = Vec::new();
    let mut requeue: Vec<InFlight> = Vec::new();
    for mut inf in group {
        if !requeue.is_empty() {
            requeue.push(inf); // preserve order behind the first bounce
            continue;
        }
        // Context-budget admission check for EVERY request: a request
        // appends prompt + n_gen tokens (prefill + one per decode step)
        // and the engine has no decode-time bound — admitting an
        // over-budget request would panic the scheduler on "quantized
        // region full" mid-decode. Sessions make this routine (history
        // accumulates across turns); huge n_gen makes it reachable even
        // on a fresh sequence.
        let held = match inf.req.session_seq {
            Some(id) => match shared.engine.seq_pos(id) {
                Ok(pos) => pos,
                Err(_) => {
                    fail(shared, &mut inf, &format!("unknown session sequence {id}"));
                    continue;
                }
            },
            None => 0,
        };
        let m = shared.engine.manifest();
        // max(1) keeps this at least as strict as the engine's own
        // prefill check (held + len + 1), which bails whole batches
        let need = inf.req.prompt.len() + inf.req.n_gen.max(1);
        if held + need > m.max_ctx + m.residual {
            fail(
                shared,
                &mut inf,
                &format!(
                    "context budget exhausted: {held} held + {need} for this \
                     request exceed T={} R={}",
                    m.max_ctx, m.residual
                ),
            );
            continue;
        }
        // session turns ride on a pre-allocated pinned sequence: no
        // allocation, no backpressure, and never freed by the scheduler
        if let Some(id) = inf.req.session_seq {
            inf.seq_id = Some(id);
            admitted.push(inf);
            continue;
        }
        match shared.engine.create_seq(&inf.req.policy) {
            Ok(id) => {
                inf.seq_id = Some(id);
                admitted.push(inf);
            }
            Err(e) => {
                match e.downcast_ref::<PoolError>() {
                    // transient: waiting will free capacity
                    Some(PoolError::BudgetExceeded { requested, budget, .. })
                        if requested <= budget =>
                    {
                        requeue.push(inf);
                    }
                    // permanent: this request can never fit — fail it
                    _ => fail(shared, &mut inf, &format!("admission failed: {e}")),
                }
            }
        }
    }
    if admitted.is_empty() {
        return (Vec::new(), requeue);
    }

    // Session turns are isolated from ordinary requests: (a) the prefix
    // cache must never see them — a turn's prompt is only the delta text,
    // so a restore would clobber the retained KV history and a snapshot
    // would poison the cache — and (b) the engine fails a prefill batch
    // as a whole, so one oversized ordinary prompt must not sink (and
    // thereby evict) an innocent session. Mixed groups therefore always
    // prefill in two engine calls, cache or no cache. Session-vs-session
    // interference within the session half is pre-empted by the context
    // check at admission above.
    let any_session = admitted.iter().any(|i| i.req.session_seq.is_some());
    let all_session = admitted.iter().all(|i| i.req.session_seq.is_some());
    if any_session && !all_session {
        let (sess_group, other_group): (Vec<InFlight>, Vec<InFlight>) = admitted
            .into_iter()
            .partition(|i| i.req.session_seq.is_some());
        let mut done = prefill_subset(shared, sess_group, false);
        done.extend(prefill_subset(shared, other_group, true));
        return (done, requeue);
    }
    let use_cache = !any_session;
    (prefill_subset(shared, admitted, use_cache), requeue)
}

/// Prefill one policy-homogeneous group with a single engine call,
/// assigning each request its first token. On engine error only THIS
/// group's requests are failed. Returns the survivors.
fn prefill_subset(
    shared: &Arc<Shared>,
    mut group: Vec<InFlight>,
    use_cache: bool,
) -> Vec<InFlight> {
    if group.is_empty() {
        return group;
    }
    let ids: Vec<u64> = group.iter().map(|i| i.seq_id.unwrap()).collect();
    let prompts: Vec<Vec<i32>> =
        group.iter().map(|i| i.req.prompt.clone()).collect();
    let n_prompt: usize = prompts.iter().map(|p| p.len()).sum();
    let result = match &shared.prefix_cache {
        Some(pc) if use_cache => shared.engine.prefill_cached(&ids, &prompts, pc),
        _ => shared.engine.prefill(&ids, &prompts),
    };
    match result {
        Ok(logits) => {
            shared.metrics.record_prefill(n_prompt);
            let now = Instant::now();
            for (inf, l) in group.iter_mut().zip(&logits) {
                let tok = sample(l, &inf.req.sampling, &mut inf.rng);
                inf.cur_token = Some(tok);
                inf.first_token_at = Some(now);
            }
            group
        }
        Err(e) => {
            for mut inf in group {
                fail(shared, &mut inf, &format!("prefill failed: {e}"));
            }
            Vec::new()
        }
    }
}

fn complete(shared: &Arc<Shared>, inf: InFlight) {
    let total = inf.submitted.elapsed().as_secs_f64();
    let ttft = inf
        .first_token_at
        .map(|t| t.duration_since(inf.submitted).as_secs_f64())
        .unwrap_or(total);
    let timing = Timing {
        queue_s: ttft, // queueing dominates TTFT in this single-device setup
        ttft_s: ttft,
        total_s: total,
        decode_steps: inf.generated.len(),
    };
    shared.metrics.record_completion(&timing, inf.generated.len());
    if let Some(id) = inf.seq_id {
        // session sequences outlive the request (freed by session close)
        if inf.req.session_seq.is_none() {
            let _ = shared.engine.free_seq(id);
        }
    }
    inf.handle.fulfill(Response {
        id: inf.req.id,
        tokens: inf.generated.clone(),
        timing,
        error: None,
    });
}

fn fail(shared: &Arc<Shared>, inf: &mut InFlight, msg: &str) {
    shared.metrics.record_failure();
    if let Some(id) = inf.seq_id.take() {
        if inf.req.session_seq.is_none() {
            let _ = shared.engine.free_seq(id);
        }
    }
    inf.handle.fulfill(Response {
        id: inf.req.id,
        tokens: inf.generated.clone(),
        timing: Timing::default(),
        error: Some(msg.to_string()),
    });
}
