//! Request/response types, the completion handle, and the per-request
//! abort flag (first-class cancellation + deadlines).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::engine::SamplingParams;
use crate::quant::QuantPolicy;

/// Callback invoked as each token is produced (streaming transports).
pub type TokenSink = Arc<dyn Fn(u64, i32) + Send + Sync>;

/// Why a request was aborted (distinct typed errors on the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AbortKind {
    /// Explicitly cancelled (`cancel` op, or the client connection died).
    Cancelled,
    /// The request's deadline passed before it completed.
    DeadlineExceeded,
}

/// Shared per-request abort flag. Cloned into the transport (which sets
/// it) and carried by the [`Request`] through the scheduler (which checks
/// it at decode-step granularity and frees the sequence's pool pages on
/// abort). First writer wins: a request cancelled and expired reports
/// whichever happened first.
#[derive(Clone, Debug, Default)]
pub struct AbortHandle {
    state: Arc<AtomicU8>, // 0 = live, 1 = cancelled, 2 = deadline expired
}

impl AbortHandle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation. Returns true if this call aborted the request
    /// (false when it was already aborted).
    pub fn cancel(&self) -> bool {
        self.state
            .compare_exchange(0, 1, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Mark the deadline as expired (scheduler-side).
    pub fn expire(&self) -> bool {
        self.state
            .compare_exchange(0, 2, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    pub fn status(&self) -> Option<AbortKind> {
        match self.state.load(Ordering::Acquire) {
            1 => Some(AbortKind::Cancelled),
            2 => Some(AbortKind::DeadlineExceeded),
            _ => None,
        }
    }

    pub fn is_aborted(&self) -> bool {
        self.status().is_some()
    }
}

#[derive(Clone)]
pub struct Request {
    /// caller-supplied id (echoed in the response)
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_gen: usize,
    pub policy: QuantPolicy,
    pub sampling: SamplingParams,
    /// stop early once the generated tail equals this token sequence
    /// (empty = never); multi-byte stop strings arrive here whole
    pub stop_seq: Vec<i32>,
    /// scheduling priority; higher runs first
    pub priority: i32,
    pub seed: u64,
    /// pre-allocated (pinned) pool sequence to generate on. Set for
    /// session turns: the scheduler skips allocation, prefills only this
    /// request's prompt on top of the retained KV state, and does NOT free
    /// the sequence on completion.
    pub session_seq: Option<u64>,
    /// resolved shared prefix (`prefix_id`): the scheduler allocates an
    /// ATTACHED sequence starting at the node's position (zero bytes
    /// copied, shared pages charged once) and prefills only `prompt` —
    /// the suffix, which may be empty (the first token then samples
    /// straight from the node's stored last-position logits, skipping
    /// prefill entirely)
    pub prefix: Option<Arc<crate::kvcache::PrefixEntry>>,
    /// per-token streaming callback (None = only the final response)
    pub on_token: Option<TokenSink>,
    /// shared abort flag: the transport cancels through it, the scheduler
    /// checks it before every decode step (and at admission)
    pub abort: AbortHandle,
    /// absolute completion deadline (from the request's `deadline_ms`);
    /// the scheduler expires the request — queued or mid-decode — once
    /// this instant passes
    pub deadline: Option<Instant>,
}

impl std::fmt::Debug for Request {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Request")
            .field("id", &self.id)
            .field("prompt_len", &self.prompt.len())
            .field("n_gen", &self.n_gen)
            .field("policy", &self.policy.name)
            .field("streaming", &self.on_token.is_some())
            .finish()
    }
}

impl Request {
    pub fn greedy(id: u64, prompt: Vec<i32>, n_gen: usize, policy: QuantPolicy) -> Self {
        Self {
            id,
            prompt,
            n_gen,
            policy,
            sampling: SamplingParams::greedy(),
            stop_seq: Vec::new(),
            priority: 0,
            seed: id,
            session_seq: None,
            prefix: None,
            on_token: None,
            abort: AbortHandle::new(),
            deadline: None,
        }
    }
}

/// Per-request timing, all in seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct Timing {
    pub queue_s: f64,
    /// time to first token (from submission)
    pub ttft_s: f64,
    pub total_s: f64,
    pub decode_steps: usize,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub timing: Timing,
    pub error: Option<String>,
    /// Set when the failure was an abort (cancel / deadline) rather than
    /// an engine error — the API layer maps this to the typed
    /// `cancelled` / `deadline_exceeded` wire codes.
    pub abort: Option<AbortKind>,
}

/// Blocking completion handle.
#[derive(Clone)]
pub struct ResponseHandle {
    inner: Arc<(Mutex<Option<Response>>, Condvar)>,
}

impl ResponseHandle {
    pub fn new() -> Self {
        Self { inner: Arc::new((Mutex::new(None), Condvar::new())) }
    }

    pub fn fulfill(&self, resp: Response) {
        let (lock, cv) = &*self.inner;
        *lock.lock().unwrap() = Some(resp);
        cv.notify_all();
    }

    pub fn wait(&self) -> Response {
        let (lock, cv) = &*self.inner;
        let mut guard = lock.lock().unwrap();
        while guard.is_none() {
            guard = cv.wait(guard).unwrap();
        }
        guard.clone().unwrap()
    }

    pub fn try_get(&self) -> Option<Response> {
        self.inner.0.lock().unwrap().clone()
    }

    /// Whether the response has been fulfilled, WITHOUT deep-cloning it
    /// (`try_get` clones the whole token vector; the scheduler polls this
    /// per active request per decode step).
    pub fn is_fulfilled(&self) -> bool {
        self.inner.0.lock().unwrap().is_some()
    }
}

impl Default for ResponseHandle {
    fn default() -> Self {
        Self::new()
    }
}

/// Internal: a request in flight through the scheduler.
pub struct InFlight {
    pub req: Request,
    pub handle: ResponseHandle,
    pub submitted: Instant,
    /// engine sequence id once admitted
    pub seq_id: Option<u64>,
    pub generated: Vec<i32>,
    /// next token to feed (set after prefill / each decode step)
    pub cur_token: Option<i32>,
    pub first_token_at: Option<Instant>,
    /// when the scheduler (most recently) admitted this request — the
    /// boundary between queue wait and service time in `Timing`
    pub admitted_at: Option<Instant>,
    pub rng: crate::util::rng::SplitMix,
}

impl InFlight {
    pub fn new(req: Request, handle: ResponseHandle) -> Self {
        let seed = req.seed;
        Self {
            req,
            handle,
            submitted: Instant::now(),
            seq_id: None,
            generated: Vec::new(),
            cur_token: None,
            first_token_at: None,
            admitted_at: None,
            rng: crate::util::rng::SplitMix::new(seed),
        }
    }

    /// Rewind to the just-submitted state for a requeue (preemption or a
    /// prefill bounce): the retry re-prefills from scratch with a reset
    /// RNG, so under greedy (or any seeded) sampling it reproduces exactly
    /// the output an uninterrupted run would have produced. `submitted`
    /// stays, so timing metrics keep charging the full client wait.
    pub fn reset_for_retry(&mut self) {
        self.seq_id = None;
        self.generated.clear();
        self.cur_token = None;
        self.first_token_at = None;
        self.admitted_at = None;
        self.rng = crate::util::rng::SplitMix::new(self.req.seed);
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.req.n_gen
            || (!self.req.stop_seq.is_empty()
                && self.generated.ends_with(&self.req.stop_seq))
    }

    /// Whether this request has been aborted: an explicit cancel (the
    /// shared flag), or its own deadline passing `now`. Deliberately does
    /// NOT write the deadline back into the shared handle — batch items
    /// share one handle for tag-level cancel but expire individually, so
    /// one item's deadline must not abort its siblings. The scheduler
    /// calls this per queued request per sweep and per active request per
    /// decode step.
    pub fn abort_status(&self, now: Instant) -> Option<AbortKind> {
        if let Some(kind) = self.req.abort.status() {
            return Some(kind);
        }
        match self.req.deadline {
            Some(deadline) if now >= deadline => {
                Some(AbortKind::DeadlineExceeded)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_fulfill_wait() {
        let h = ResponseHandle::new();
        let h2 = h.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            h2.fulfill(Response {
                id: 7,
                tokens: vec![1, 2],
                timing: Timing::default(),
                error: None,
                abort: None,
            });
        });
        let r = h.wait();
        assert_eq!(r.id, 7);
        assert_eq!(r.tokens, vec![1, 2]);
        assert!(h.try_get().is_some());
    }

    #[test]
    fn abort_flag_first_writer_wins_and_deadline_is_local() {
        let h = AbortHandle::new();
        assert_eq!(h.status(), None);
        assert!(h.cancel());
        assert!(!h.expire(), "cancel already latched");
        assert_eq!(h.status(), Some(AbortKind::Cancelled));

        // deadline path through InFlight::abort_status
        let mut req = Request::greedy(9, vec![65], 4, QuantPolicy::float32(1));
        req.deadline = Some(Instant::now() - std::time::Duration::from_millis(1));
        let inf = InFlight::new(req, ResponseHandle::new());
        assert_eq!(
            inf.abort_status(Instant::now()),
            Some(AbortKind::DeadlineExceeded)
        );
        // the deadline is NOT written into the shared handle: a sibling
        // request sharing this handle (batch items under one tag) must
        // not see its brother's expiry
        assert_eq!(inf.req.abort.status(), None);
        // an explicit cancel takes precedence in the report
        assert!(inf.req.abort.cancel());
        assert_eq!(
            inf.abort_status(Instant::now()),
            Some(AbortKind::Cancelled)
        );
    }

    #[test]
    fn inflight_done_conditions() {
        let req = Request::greedy(1, vec![65], 2, QuantPolicy::float32(1));
        let mut inf = InFlight::new(req, ResponseHandle::new());
        assert!(!inf.done());
        inf.generated = vec![10, 11];
        assert!(inf.done());

        // multi-token stop sequence: only the exact tail terminates
        let mut req2 = Request::greedy(2, vec![65], 10, QuantPolicy::float32(1));
        req2.stop_seq = vec![10, 46];
        let mut inf2 = InFlight::new(req2, ResponseHandle::new());
        inf2.generated = vec![9, 46];
        assert!(!inf2.done(), "suffix mismatch must not stop");
        inf2.generated = vec![9, 10, 46];
        assert!(inf2.done());
    }
}
