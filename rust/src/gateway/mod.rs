//! HTTP/SSE gateway over a replica fleet — one engine becomes a
//! horizontally scalable service.
//!
//! The gateway is a dependency-free HTTP/1.1 front end (hand-rolled
//! parsing over `std::net`, [`routes`] typed route table, JSON via
//! `util::json`) that speaks the v3 multiplexed wire protocol to N
//! engine replicas through [`crate::server::MuxClient`]. It owns the
//! fleet-level concerns the per-process server cannot:
//!
//! * **Routing** ([`router::ReplicaRegistry`]): session affinity (a
//!   session is pinned to the replica that opened it, forever),
//!   shared-prefix-aware placement (requests naming a `prefix_id` go to
//!   a replica where that prefix is resident), least-inflight fallback,
//!   and load shedding with typed 429s once a replica's in-flight count
//!   hits the configured cap.
//! * **Streaming**: every streaming operation is exposed as one SSE
//!   stream (`token` events, then a terminal `done`/`error` event — see
//!   [`sse`]). A client hang-up mid-stream propagates a `cancel` to the
//!   replica so fleet capacity is reclaimed.
//! * **Drain** (`POST /v1/admin/drain`): takes one replica out of
//!   rotation — new work is refused with typed `draining` errors while
//!   every in-flight stream runs to completion, then the replica
//!   releases its shared prefixes and stops accepting. Pinned sessions
//!   are never migrated (their KV state lives in the replica's pools);
//!   their next turn gets the typed error instead.
//! * **Fleet stats** (`GET /v1/stats`): per-replica `stats` replies
//!   merged into one fleet view (counters summed, watermarks maxed)
//!   with the raw per-replica breakdown alongside.
//!
//! Replica failure is typed end to end: a dead connection surfaces as
//! `replica_unavailable` (never a hang), the replica is evicted from
//! rotation, and placement-routed requests retry on a survivor.

pub mod http;
pub mod router;
pub mod routes;
pub mod sse;
pub mod testing;

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::api::{self, ApiError, ApiRequest, ErrorCode};
use crate::server::{MuxClient, MuxPending};
use crate::util::json::Value;

use http::HttpRequest;
use router::{ReplicaRegistry, RouteHint};
use routes::{Route, RouteFailure};

/// Gateway tunables. `Default` suits tests and small fleets.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Per-replica in-flight cap before the router sheds with a typed
    /// 429 (`capacity`). 0 disables shedding.
    pub shed_inflight: u64,
    /// Deadline injected into generation ops whose body sets none.
    pub default_deadline_ms: Option<u64>,
    /// Emit one structured JSON log line per request to stderr.
    pub log_requests: bool,
    /// Model depth for request validation (layer-wise policy strings).
    /// 0 = probe it from the first replica's `policies` reply at bind.
    pub n_layers: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            shed_inflight: 256,
            default_deadline_ms: None,
            log_requests: false,
            n_layers: 0,
        }
    }
}

/// The HTTP front end. Bind with [`Gateway::bind`], run with
/// [`Gateway::serve`] (blocking accept loop; spawn a thread to run it
/// alongside other work), stop with [`Gateway::request_stop`].
pub struct Gateway {
    listener: TcpListener,
    stop: AtomicBool,
    registry: ReplicaRegistry,
    /// Connection slots, parallel to the registry's replica indices.
    /// `None` once evicted. In-flight handlers hold their own `Arc`
    /// clone, so dropping a slot never severs a stream mid-flight —
    /// the socket shuts down when the last handler finishes.
    clients: Mutex<Vec<Option<Arc<MuxClient>>>>,
    n_layers: usize,
    default_deadline_ms: Option<u64>,
    log_requests: bool,
}

/// Result of one handled HTTP request, for logging and keep-alive.
struct Outcome {
    status: u16,
    /// Typed error code, when the reply (or terminal SSE event) was one.
    code: Option<String>,
    /// Replica that served the request, when exactly one did.
    replica: Option<String>,
    /// False once this connection cannot carry another request (SSE
    /// always closes; so do write failures).
    open: bool,
}

/// `{"error":{"code":…,"message":…}}` — the HTTP error body shape.
fn error_body(e: &ApiError) -> Value {
    Value::obj(vec![(
        "error",
        Value::obj(vec![
            ("code", Value::str_of(e.code.as_str())),
            ("message", Value::str_of(e.message.clone())),
        ]),
    )])
}

/// The typed code inside an error reply, if the value is one.
fn error_code_of(v: &Value) -> Option<String> {
    v.get("error").get("code").as_str().map(str::to_string)
}

/// Map a typed error-code string to its HTTP status. The full table
/// lives in docs/API.md; everything unlisted is a 400-class validation
/// failure (`bad_json`, `bad_field`, `missing_field`, …).
pub fn status_for_code(code: &str) -> u16 {
    match code {
        "unknown_session" | "unknown_prefix" | "unknown_op" => 404,
        "session_busy" | "prefix_policy_mismatch" => 409,
        "capacity" | "too_many_inflight" => 429,
        "cancelled" => 499,
        "draining" | "replica_unavailable" => 503,
        "deadline_exceeded" => 504,
        "engine" | "internal" => 500,
        _ => 400,
    }
}

/// Drop the wire-framing fields (`v`, `tag`, `done`) from a reply frame
/// so HTTP bodies and SSE event data carry only the operation schema.
fn strip_wire(mut v: Value) -> Value {
    if let Value::Obj(o) = &mut v {
        o.remove("v");
        o.remove("tag");
        o.remove("done");
    }
    v
}

/// Fleet-stats merge: keys where the fleet value is the per-replica
/// maximum (watermarks, clocks, latency percentiles) rather than a sum.
fn merged_as_max(key: &str) -> bool {
    matches!(key, "elapsed_s" | "inflight_peak" | "mean_batch")
        || key.ends_with("_p50_s")
        || key.ends_with("_p95_s")
}

/// Merge per-replica stats objects into one fleet object: numeric
/// fields sum (counters, throughput, accumulated seconds) except the
/// [`merged_as_max`] watermark keys; nested objects merge recursively.
fn merge_stats(values: &[Value]) -> Value {
    let mut keys: Vec<String> = Vec::new();
    for v in values {
        if let Value::Obj(o) = v {
            for k in o.keys() {
                if !keys.contains(k) {
                    keys.push(k.clone());
                }
            }
        }
    }
    let mut out = Vec::new();
    for key in keys {
        let present: Vec<&Value> = values
            .iter()
            .map(|v| v.get(&key))
            .filter(|v| !matches!(v, Value::Null))
            .collect();
        let Some(first) = present.first() else { continue };
        let merged = match first {
            Value::Obj(_) => {
                let children: Vec<Value> =
                    present.iter().map(|v| (*v).clone()).collect();
                merge_stats(&children)
            }
            Value::Num(_) => {
                let nums = present.iter().filter_map(|v| v.as_f64());
                if merged_as_max(&key) {
                    Value::num(nums.fold(f64::NEG_INFINITY, f64::max))
                } else {
                    Value::num(nums.sum())
                }
            }
            other => (*other).clone(),
        };
        out.push((key, merged));
    }
    Value::Obj(out.into_iter().collect())
}

impl Gateway {
    /// Connect to every replica, probe the model depth (unless given),
    /// and bind the HTTP listener. Fails if any replica is unreachable —
    /// a fleet that starts degraded is a misconfiguration, not a state
    /// to route around silently.
    pub fn bind(
        addr: &str,
        replicas: &[String],
        cfg: GatewayConfig,
    ) -> Result<Self> {
        anyhow::ensure!(
            !replicas.is_empty(),
            "a gateway needs at least one replica address"
        );
        let registry = ReplicaRegistry::new(cfg.shed_inflight);
        let mut clients = Vec::new();
        for r in replicas {
            let c = MuxClient::connect(r)
                .with_context(|| format!("connecting to replica {r}"))?;
            registry.add(r);
            clients.push(Some(Arc::new(c)));
        }
        let n_layers = if cfg.n_layers > 0 {
            cfg.n_layers
        } else {
            let first = clients[0].as_ref().expect("slot just filled");
            let reply = first
                .submit(&ApiRequest::Policies { policy: None })?
                .wait_done()
                .context("probing n_layers via the policies op")?;
            reply.get("n_layers").as_usize().ok_or_else(|| {
                anyhow::anyhow!(
                    "replica {} policies reply carries no n_layers: {reply}",
                    replicas[0]
                )
            })?
        };
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding gateway on {addr}"))?;
        Ok(Self {
            listener,
            stop: AtomicBool::new(false),
            registry,
            clients: Mutex::new(clients),
            n_layers,
            default_deadline_ms: cfg.default_deadline_ms,
            log_requests: cfg.log_requests,
        })
    }

    pub fn local_addr(&self) -> String {
        self.listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_default()
    }

    /// The routing table (used by tests and the `/v1/replicas` route).
    pub fn registry(&self) -> &ReplicaRegistry {
        &self.registry
    }

    /// Ask the accept loop to exit (same self-connect wakeup as
    /// `Server::request_stop`). Open connections finish their current
    /// request; no new connections are accepted.
    pub fn request_stop(&self) {
        use std::net::{IpAddr, Ipv4Addr, Ipv6Addr};
        self.stop.store(true, Ordering::SeqCst);
        if let Ok(mut addr) = self.listener.local_addr() {
            if addr.ip().is_unspecified() {
                addr.set_ip(match addr.ip() {
                    IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect(addr);
        }
    }

    /// Accept loop (blocks): one handler thread per connection.
    pub fn serve(self: &Arc<Self>) -> Result<()> {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.stop.load(Ordering::SeqCst) {
                        return Ok(()); // wakeup connection; drop it
                    }
                    let gw = self.clone();
                    std::thread::spawn(move || gw.handle_conn(stream));
                }
                Err(e) => {
                    if self.stop.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                    return Err(e.into());
                }
            }
        }
    }

    fn client(&self, idx: usize) -> Option<Arc<MuxClient>> {
        self.clients.lock().unwrap().get(idx).cloned().flatten()
    }

    /// Take a replica out of rotation: the registry forgets its
    /// residency and the connection slot is dropped. Handlers that are
    /// mid-stream keep their own `Arc`, so their frames still deliver.
    fn evict(&self, idx: usize) {
        self.registry.evict(idx);
        if let Some(slot) = self.clients.lock().unwrap().get_mut(idx) {
            *slot = None;
        }
    }

    fn log(&self, req: &HttpRequest, out: &Outcome, started: Instant) {
        if !self.log_requests {
            return;
        }
        let mut fields = vec![
            ("at", Value::str_of("gateway")),
            ("method", Value::str_of(req.method.clone())),
            ("path", Value::str_of(req.path.clone())),
            ("status", Value::num(out.status as f64)),
            (
                "ms",
                Value::num(
                    (started.elapsed().as_secs_f64() * 1e4).round() / 10.0,
                ),
            ),
        ];
        if let Some(c) = &out.code {
            fields.push(("code", Value::str_of(c.clone())));
        }
        if let Some(r) = &out.replica {
            fields.push(("replica", Value::str_of(r.clone())));
        }
        eprintln!("{}", Value::obj(fields));
    }

    fn handle_conn(self: Arc<Self>, stream: TcpStream) {
        let Ok(rstream) = stream.try_clone() else { return };
        let mut reader = BufReader::new(rstream);
        let mut w = stream;
        loop {
            let started = Instant::now();
            let req = match http::read_request(&mut reader) {
                Ok(Some(r)) => r,
                Ok(None) | Err(http::HttpParseError::Io(_)) => return,
                Err(http::HttpParseError::Malformed(m)) => {
                    let _ = http::write_json(
                        &mut w,
                        400,
                        &error_body(&ApiError::bad_json(m)),
                        false,
                    );
                    return;
                }
                Err(http::HttpParseError::BodyTooLarge(n)) => {
                    let e = ApiError::new(
                        ErrorCode::Capacity,
                        format!(
                            "request body of {n} bytes exceeds the \
                             {}-byte limit",
                            http::MAX_BODY_BYTES
                        ),
                    );
                    let _ =
                        http::write_json(&mut w, 413, &error_body(&e), false);
                    return;
                }
            };
            let keep = req.keep_alive() && !self.stop.load(Ordering::SeqCst);
            let out = self.handle_request(&req, &mut w, keep);
            self.log(&req, &out, started);
            if !out.open {
                return;
            }
        }
    }

    /// Write a JSON reply and fold it into an [`Outcome`].
    fn reply_json(
        &self,
        w: &mut TcpStream,
        status: u16,
        body: &Value,
        keep: bool,
        replica: Option<String>,
    ) -> Outcome {
        let wrote = http::write_json(w, status, body, keep).is_ok();
        Outcome {
            status,
            code: error_code_of(body),
            replica,
            open: keep && wrote,
        }
    }

    fn reply_error(
        &self,
        w: &mut TcpStream,
        status: u16,
        e: &ApiError,
        keep: bool,
    ) -> Outcome {
        self.reply_json(w, status, &error_body(e), keep, None)
    }

    fn handle_request(
        &self,
        req: &HttpRequest,
        w: &mut TcpStream,
        keep: bool,
    ) -> Outcome {
        let m = match routes::resolve(&req.method, &req.path) {
            Ok(m) => m,
            Err(RouteFailure::NotFound) => {
                let e = ApiError::new(
                    ErrorCode::UnknownOp,
                    format!("no route for {} {}", req.method, req.path),
                );
                return self.reply_error(w, 404, &e, keep);
            }
            Err(RouteFailure::MethodNotAllowed(allow)) => {
                let e = ApiError::new(
                    ErrorCode::UnknownOp,
                    format!(
                        "{} does not support {}; allowed: {allow}",
                        req.path, req.method
                    ),
                );
                return self.reply_error(w, 405, &e, keep);
            }
        };
        match m.route {
            Route::Health => self.handle_health(w, keep),
            Route::Stats => self.handle_stats(w, keep),
            Route::Replicas => self.handle_replicas(w, keep),
            Route::Policies => self.handle_policies(w, keep),
            Route::Generate => self.handle_generate(req, w, keep),
            Route::SessionOpen => self.handle_session_open(req, w, keep),
            Route::SessionTurn => {
                self.handle_session_turn(req, &m.params[0], w, keep)
            }
            Route::SessionClose => {
                self.handle_session_close(&m.params[0], w, keep)
            }
            Route::PrefixList => self.handle_prefix_list(w, keep),
            Route::PrefixRegister => {
                self.handle_prefix_register(req, w, keep)
            }
            Route::PrefixRelease => {
                self.handle_prefix_release(&m.params[0], w, keep)
            }
            Route::Drain => self.handle_drain(req, w, keep),
        }
    }

    // ------------------------------------------------------------------
    // request synthesis + routed submission
    // ------------------------------------------------------------------

    /// Build a typed [`ApiRequest`] from an HTTP body: the body is the
    /// operation's v3 object minus the wire framing, which the gateway
    /// injects before running the line through the SAME strict decoder
    /// the replicas use — HTTP clients get byte-identical validation
    /// (typed `bad_field`/`missing_field`/… errors) to socket clients.
    fn decode_body_op(
        &self,
        req: &HttpRequest,
        op: &str,
        extra: &[(&str, Value)],
        inject_deadline: bool,
    ) -> Result<ApiRequest, (u16, ApiError)> {
        let body = req
            .body_object()
            .map_err(|m| (400, ApiError::bad_json(m)))?;
        let Value::Obj(mut o) = body else { unreachable!() };
        for k in ["v", "op", "tag", "done"] {
            if o.contains_key(k) {
                return Err((
                    400,
                    ApiError::bad_field(
                        k,
                        "wire-framing field; not allowed in an HTTP body",
                    ),
                ));
            }
        }
        for (k, v) in extra {
            if o.contains_key(*k) {
                return Err((
                    400,
                    ApiError::bad_field(
                        k,
                        "set by the route path; not allowed in the body",
                    ),
                ));
            }
            o.insert((*k).to_string(), v.clone());
        }
        o.insert("v".to_string(), Value::num(3.0));
        o.insert("op".to_string(), Value::str_of(op));
        o.insert("tag".to_string(), Value::num(0.0));
        if inject_deadline {
            if let Some(ms) = self.default_deadline_ms {
                o.entry("deadline_ms".to_string())
                    .or_insert(Value::num(ms as f64));
            }
        }
        let line = Value::Obj(o).to_string();
        match api::decode_frame(&line, self.n_layers) {
            Ok(f) => Ok(f.req),
            Err(de) => {
                Err((status_for_code(de.error.code.as_str()), de.error))
            }
        }
    }

    /// Route + submit with replica-failure recovery: a dead connection
    /// evicts the replica and (for `Any`/`Prefix` placement) retries on
    /// a survivor. Session-pinned requests never retry elsewhere — the
    /// session's KV state died with its replica.
    /// On success the registry's in-flight count for the chosen replica
    /// is held; every exit path must pair it with `end_request`.
    fn submit_routed(
        &self,
        hint: RouteHint<'_>,
        req: &ApiRequest,
    ) -> Result<(usize, Arc<MuxClient>, MuxPending), (u16, ApiError)> {
        let attempts =
            if matches!(hint, RouteHint::Session(_)) { 1 } else { 3 };
        for _ in 0..attempts {
            let idx = self.registry.route(hint).map_err(|e| {
                let api = e.to_api_error();
                (status_for_code(api.code.as_str()), api)
            })?;
            let client = match self.client(idx) {
                Some(c) if !c.is_closed() => c,
                _ => {
                    self.registry.end_request(idx);
                    self.evict(idx);
                    continue;
                }
            };
            match client.submit(req) {
                Ok(p) => return Ok((idx, client, p)),
                Err(_) => {
                    self.registry.end_request(idx);
                    self.evict(idx);
                    continue;
                }
            }
        }
        Err((
            503,
            ApiError::replica_unavailable(
                "replica connection failed and no retry succeeded",
            ),
        ))
    }

    /// Wait for a unary (non-streaming) reply. `counted` releases the
    /// in-flight hold taken by `submit_routed`.
    fn wait_unary(
        &self,
        idx: usize,
        pending: &MuxPending,
        counted: bool,
    ) -> (u16, Value) {
        let result = pending.wait_done();
        if counted {
            self.registry.end_request(idx);
        }
        match result {
            Ok(frame) => {
                let body = strip_wire(frame);
                match error_code_of(&body) {
                    Some(code) => {
                        if code == "replica_unavailable" {
                            self.evict(idx);
                        }
                        (status_for_code(&code), body)
                    }
                    None => (200, body),
                }
            }
            Err(_) => {
                self.evict(idx);
                (
                    503,
                    error_body(&ApiError::replica_unavailable(
                        "replica connection closed mid-request",
                    )),
                )
            }
        }
    }

    /// Relay a streaming reply as one SSE stream: `token` events, then
    /// a terminal `done` or `error` event. A client hang-up propagates
    /// a cancel to the replica. SSE connections never keep-alive.
    fn stream_reply(
        &self,
        idx: usize,
        client: &Arc<MuxClient>,
        pending: &MuxPending,
        w: &mut TcpStream,
    ) -> Outcome {
        let replica = Some(self.registry.name_of(idx));
        if http::write_sse_header(w).is_err() {
            let _ = client.cancel(pending.tag);
            self.registry.end_request(idx);
            return Outcome {
                status: 200,
                code: Some("client_gone".into()),
                replica,
                open: false,
            };
        }
        loop {
            let Ok(frame) = pending.recv() else {
                // the reader thread now always fails pendings with a
                // typed frame; a raw channel error means it is gone too
                let e = ApiError::replica_unavailable(
                    "replica connection closed mid-stream",
                );
                let _ = sse::write_event(w, sse::EVENT_ERROR, &error_body(&e));
                self.registry.end_request(idx);
                self.evict(idx);
                return Outcome {
                    status: 200,
                    code: Some("replica_unavailable".into()),
                    replica,
                    open: false,
                };
            };
            let done = frame.get("done").as_bool() == Some(true);
            let body = strip_wire(frame);
            if !done {
                if sse::write_event(w, sse::EVENT_TOKEN, &body).is_err() {
                    // client hung up: reclaim the replica's capacity
                    let _ = client.cancel(pending.tag);
                    self.registry.end_request(idx);
                    return Outcome {
                        status: 499,
                        code: Some("client_gone".into()),
                        replica,
                        open: false,
                    };
                }
                continue;
            }
            let code = error_code_of(&body);
            let event = if code.is_some() {
                sse::EVENT_ERROR
            } else {
                sse::EVENT_DONE
            };
            let _ = sse::write_event(w, event, &body);
            self.registry.end_request(idx);
            if code.as_deref() == Some("replica_unavailable") {
                self.evict(idx);
            }
            return Outcome { status: 200, code, replica, open: false };
        }
    }

    // ------------------------------------------------------------------
    // route handlers
    // ------------------------------------------------------------------

    fn handle_health(&self, w: &mut TcpStream, keep: bool) -> Outcome {
        let views = self.registry.views();
        let ok = views.iter().any(|v| v.live && !v.draining);
        let replicas = views
            .iter()
            .map(|v| {
                Value::obj(vec![
                    ("name", Value::str_of(v.name.clone())),
                    ("live", Value::Bool(v.live)),
                    ("draining", Value::Bool(v.draining)),
                    ("inflight", Value::num(v.inflight as f64)),
                    ("sessions", Value::num(v.sessions as f64)),
                ])
            })
            .collect();
        let body = Value::obj(vec![
            ("ok", Value::Bool(ok)),
            ("replicas", Value::Arr(replicas)),
        ]);
        self.reply_json(w, if ok { 200 } else { 503 }, &body, keep, None)
    }

    fn handle_replicas(&self, w: &mut TcpStream, keep: bool) -> Outcome {
        let replicas = self
            .registry
            .views()
            .into_iter()
            .map(|v| {
                Value::obj(vec![
                    ("name", Value::str_of(v.name)),
                    ("live", Value::Bool(v.live)),
                    ("draining", Value::Bool(v.draining)),
                    ("inflight", Value::num(v.inflight as f64)),
                    ("sessions", Value::num(v.sessions as f64)),
                    (
                        "prefixes",
                        Value::arr(
                            v.prefixes.into_iter().map(Value::str_of).collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let s = self.registry.stats();
        let body = Value::obj(vec![
            ("replicas", Value::Arr(replicas)),
            (
                "router",
                Value::obj(vec![
                    ("routed", Value::num(s.routed as f64)),
                    ("affinity_routes", Value::num(s.affinity_routes as f64)),
                    ("prefix_local", Value::num(s.prefix_local as f64)),
                    ("prefix_fallback", Value::num(s.prefix_fallback as f64)),
                    ("shed", Value::num(s.shed as f64)),
                    (
                        "refused_unavailable",
                        Value::num(s.refused_unavailable as f64),
                    ),
                ]),
            ),
        ]);
        self.reply_json(w, 200, &body, keep, None)
    }

    fn handle_policies(&self, w: &mut TcpStream, keep: bool) -> Outcome {
        let req = ApiRequest::Policies { policy: None };
        let (idx, _client, pending) =
            match self.submit_routed(RouteHint::Any, &req) {
                Ok(t) => t,
                Err((status, e)) => return self.reply_error(w, status, &e, keep),
            };
        let (status, body) = self.wait_unary(idx, &pending, true);
        self.reply_json(w, status, &body, keep, Some(self.registry.name_of(idx)))
    }

    fn handle_generate(
        &self,
        req: &HttpRequest,
        w: &mut TcpStream,
        keep: bool,
    ) -> Outcome {
        let typed = match self.decode_body_op(req, "generate", &[], true) {
            Ok(t) => t,
            Err((status, e)) => return self.reply_error(w, status, &e, keep),
        };
        let ApiRequest::Generate(spec) = &typed else { unreachable!() };
        let stream = spec.stream;
        let hint = match &spec.prefix_id {
            Some(p) => RouteHint::Prefix(p),
            None => RouteHint::Any,
        };
        let (idx, client, pending) = match self.submit_routed(hint, &typed) {
            Ok(t) => t,
            Err((status, e)) => return self.reply_error(w, status, &e, keep),
        };
        if stream {
            self.stream_reply(idx, &client, &pending, w)
        } else {
            let (status, body) = self.wait_unary(idx, &pending, true);
            self.reply_json(
                w,
                status,
                &body,
                keep,
                Some(self.registry.name_of(idx)),
            )
        }
    }

    fn handle_session_open(
        &self,
        req: &HttpRequest,
        w: &mut TcpStream,
        keep: bool,
    ) -> Outcome {
        let typed = match self.decode_body_op(req, "session_open", &[], false)
        {
            Ok(t) => t,
            Err((status, e)) => return self.reply_error(w, status, &e, keep),
        };
        let ApiRequest::SessionOpen { prefix_id, .. } = &typed else {
            unreachable!()
        };
        let hint = match prefix_id {
            Some(p) => RouteHint::Prefix(p),
            None => RouteHint::Any,
        };
        let (idx, _client, pending) = match self.submit_routed(hint, &typed) {
            Ok(t) => t,
            Err((status, e)) => return self.reply_error(w, status, &e, keep),
        };
        let (status, mut body) = self.wait_unary(idx, &pending, true);
        let name = self.registry.name_of(idx);
        if status == 200 {
            let Some(remote) = body.get("session").as_i64() else {
                let e = ApiError::new(
                    ErrorCode::Internal,
                    format!("replica session_open reply has no id: {body}"),
                );
                return self.reply_error(w, 500, &e, keep);
            };
            // hand the client a GATEWAY-namespaced id: replica-local ids
            // collide across the fleet
            let gw_id = self.registry.pin_session(idx, remote as u64);
            if let Value::Obj(o) = &mut body {
                o.insert("session".into(), Value::num(gw_id as f64));
                o.insert("replica".into(), Value::str_of(name.clone()));
            }
        }
        self.reply_json(w, status, &body, keep, Some(name))
    }

    fn handle_session_turn(
        &self,
        req: &HttpRequest,
        id_param: &str,
        w: &mut TcpStream,
        keep: bool,
    ) -> Outcome {
        let Ok(gw_id) = id_param.parse::<u64>() else {
            let e = ApiError::bad_field("session", "path id must be a u64");
            return self.reply_error(w, 400, &e, keep);
        };
        let Some(pin) = self.registry.session_pin(gw_id) else {
            return self.reply_error(
                w,
                404,
                &ApiError::unknown_session(gw_id),
                keep,
            );
        };
        let extra = [("session", Value::num(pin.remote as f64))];
        let typed =
            match self.decode_body_op(req, "session_append", &extra, true) {
                Ok(t) => t,
                Err((status, e)) => {
                    return self.reply_error(w, status, &e, keep)
                }
            };
        let stream = matches!(
            &typed,
            ApiRequest::SessionAppend { spec, .. } if spec.stream
        );
        let (idx, client, pending) =
            match self.submit_routed(RouteHint::Session(gw_id), &typed) {
                Ok(t) => t,
                Err((status, e)) => {
                    return self.reply_error(w, status, &e, keep)
                }
            };
        if stream {
            self.stream_reply(idx, &client, &pending, w)
        } else {
            let (status, mut body) = self.wait_unary(idx, &pending, true);
            if let Value::Obj(o) = &mut body {
                // replies echo the replica-local id; restore ours
                if o.contains_key("session") {
                    o.insert("session".into(), Value::num(gw_id as f64));
                }
            }
            self.reply_json(
                w,
                status,
                &body,
                keep,
                Some(self.registry.name_of(idx)),
            )
        }
    }

    fn handle_session_close(
        &self,
        id_param: &str,
        w: &mut TcpStream,
        keep: bool,
    ) -> Outcome {
        let Ok(gw_id) = id_param.parse::<u64>() else {
            let e = ApiError::bad_field("session", "path id must be a u64");
            return self.reply_error(w, 400, &e, keep);
        };
        let Some(pin) = self.registry.session_pin(gw_id) else {
            return self.reply_error(
                w,
                404,
                &ApiError::unknown_session(gw_id),
                keep,
            );
        };
        let name = self.registry.name_of(pin.replica);
        let gone = |this: &Self| {
            this.registry.unpin_session(gw_id);
            Value::obj(vec![
                ("session", Value::num(gw_id as f64)),
                ("closed", Value::Bool(true)),
                ("replica_gone", Value::Bool(true)),
            ])
        };
        // closes stay admissible on a DRAINING replica (clients must be
        // able to wind down), so bypass route() and talk to the pin
        let client = match self.client(pin.replica) {
            Some(c) if self.registry.is_live(pin.replica) && !c.is_closed() => {
                c
            }
            _ => {
                // the replica (and the session's KV state) is gone;
                // report it closed rather than erroring a no-op
                let body = gone(self);
                return self.reply_json(w, 200, &body, keep, Some(name));
            }
        };
        let req = ApiRequest::SessionClose { session: pin.remote };
        let pending = match client.submit(&req) {
            Ok(p) => p,
            Err(_) => {
                self.evict(pin.replica);
                let body = gone(self);
                return self.reply_json(w, 200, &body, keep, Some(name));
            }
        };
        let (status, mut body) = self.wait_unary(pin.replica, &pending, false);
        match error_code_of(&body).as_deref() {
            None => {
                self.registry.unpin_session(gw_id);
                if let Value::Obj(o) = &mut body {
                    o.insert("session".into(), Value::num(gw_id as f64));
                    o.insert("replica".into(), Value::str_of(name.clone()));
                }
                self.reply_json(w, status, &body, keep, Some(name))
            }
            Some("unknown_session") => {
                // stale pin (replica evicted it, e.g. idle sweep)
                self.registry.unpin_session(gw_id);
                self.reply_json(w, status, &body, keep, Some(name))
            }
            Some("replica_unavailable") => {
                let body = gone(self);
                self.reply_json(w, 200, &body, keep, Some(name))
            }
            Some(_) => self.reply_json(w, status, &body, keep, Some(name)),
        }
    }

    fn handle_prefix_list(&self, w: &mut TcpStream, keep: bool) -> Outcome {
        let mut pendings = Vec::new();
        for idx in self.registry.live_indices() {
            let Some(client) = self.client(idx) else { continue };
            match client.submit(&ApiRequest::Prefixes) {
                Ok(p) => pendings.push((idx, p)),
                Err(_) => self.evict(idx),
            }
        }
        let mut rows = Vec::new();
        for (idx, p) in pendings {
            let (status, body) = self.wait_unary(idx, &p, false);
            if status != 200 {
                continue;
            }
            let name = self.registry.name_of(idx);
            if let Some(list) = body.get("prefixes").as_arr() {
                for row in list {
                    let mut row = row.clone();
                    if let Value::Obj(o) = &mut row {
                        o.insert("replica".into(), Value::str_of(name.clone()));
                        // keep the registry's residency map honest even
                        // if a prefix was registered out of band
                        if let Some(n) = o.get("name").and_then(|v| v.as_str())
                        {
                            self.registry.note_prefix(idx, n);
                        }
                    }
                    rows.push(row);
                }
            }
        }
        let body = Value::obj(vec![
            ("n", Value::num(rows.len() as f64)),
            ("prefixes", Value::Arr(rows)),
        ]);
        self.reply_json(w, 200, &body, keep, None)
    }

    fn handle_prefix_register(
        &self,
        req: &HttpRequest,
        w: &mut TcpStream,
        keep: bool,
    ) -> Outcome {
        let typed =
            match self.decode_body_op(req, "prefix_register", &[], false) {
                Ok(t) => t,
                Err((status, e)) => {
                    return self.reply_error(w, status, &e, keep)
                }
            };
        let ApiRequest::PrefixRegister { name, .. } = &typed else {
            unreachable!()
        };
        let targets = self.registry.admissible_indices();
        if targets.is_empty() {
            let e = if self.registry.live_indices().is_empty() {
                ApiError::replica_unavailable("no live replicas")
            } else {
                ApiError::draining()
            };
            return self.reply_error(w, 503, &e, keep);
        }
        // fan out: submit everywhere first (prefill runs on every
        // replica concurrently), then collect
        let mut pendings = Vec::new();
        let mut failed = Vec::new();
        for idx in targets {
            match self.client(idx) {
                Some(client) => match client.submit(&typed) {
                    Ok(p) => pendings.push((idx, p)),
                    Err(_) => {
                        self.evict(idx);
                        failed.push((idx, None));
                    }
                },
                None => failed.push((idx, None)),
            }
        }
        let mut registered = Vec::new();
        let mut first_ok: Option<Value> = None;
        let mut first_err: Option<(u16, Value)> = None;
        for (idx, p) in pendings {
            let (status, body) = self.wait_unary(idx, &p, false);
            if status == 200 {
                self.registry.note_prefix(idx, name);
                registered.push(self.registry.name_of(idx));
                first_ok.get_or_insert(body);
            } else {
                if first_err.is_none() {
                    first_err = Some((status, body.clone()));
                }
                failed.push((idx, error_code_of(&body)));
            }
        }
        if registered.is_empty() {
            let (status, body) = first_err.unwrap_or((
                503,
                error_body(&ApiError::replica_unavailable(
                    "every replica connection failed during registration",
                )),
            ));
            return self.reply_json(w, status, &body, keep, None);
        }
        let mut body = first_ok.expect("at least one success");
        if let Value::Obj(o) = &mut body {
            o.insert(
                "replicas".into(),
                Value::arr(
                    registered.iter().cloned().map(Value::str_of).collect(),
                ),
            );
            o.insert(
                "failed".into(),
                Value::arr(
                    failed
                        .iter()
                        .map(|(idx, code)| {
                            Value::obj(vec![
                                (
                                    "replica",
                                    Value::str_of(self.registry.name_of(*idx)),
                                ),
                                (
                                    "code",
                                    code.clone()
                                        .map(Value::str_of)
                                        .unwrap_or(Value::str_of(
                                            "replica_unavailable",
                                        )),
                                ),
                            ])
                        })
                        .collect(),
                ),
            );
        }
        self.reply_json(w, 200, &body, keep, None)
    }

    fn handle_prefix_release(
        &self,
        name: &str,
        w: &mut TcpStream,
        keep: bool,
    ) -> Outcome {
        let holders = self.registry.prefix_holders(name);
        let targets = if holders.is_empty() {
            // residency map may be stale (out-of-band registration);
            // try everywhere still live
            self.registry.live_indices()
        } else {
            holders
        };
        let mut pendings = Vec::new();
        for idx in targets {
            let Some(client) = self.client(idx) else { continue };
            match client
                .submit(&ApiRequest::PrefixRelease { name: name.into() })
            {
                Ok(p) => pendings.push((idx, p)),
                Err(_) => self.evict(idx),
            }
        }
        let mut released = Vec::new();
        let mut missing = 0usize;
        let mut other_err: Option<(u16, Value)> = None;
        for (idx, p) in pendings {
            let (status, body) = self.wait_unary(idx, &p, false);
            match error_code_of(&body).as_deref() {
                None => released.push(self.registry.name_of(idx)),
                Some("unknown_prefix") => missing += 1,
                Some(_) => {
                    if other_err.is_none() {
                        other_err = Some((status, body));
                    }
                }
            }
        }
        self.registry.forget_prefix(name);
        if released.is_empty() {
            if let Some((status, body)) = other_err {
                return self.reply_json(w, status, &body, keep, None);
            }
            let e = ApiError::new(
                ErrorCode::UnknownPrefix,
                format!("prefix '{name}' is not registered on any replica"),
            );
            return self.reply_error(w, 404, &e, keep);
        }
        let body = Value::obj(vec![
            ("name", Value::str_of(name)),
            (
                "released",
                Value::arr(released.into_iter().map(Value::str_of).collect()),
            ),
            ("missing", Value::num(missing as f64)),
        ]);
        self.reply_json(w, 200, &body, keep, None)
    }

    fn handle_stats(&self, w: &mut TcpStream, keep: bool) -> Outcome {
        let mut pendings = Vec::new();
        for idx in self.registry.live_indices() {
            let Some(client) = self.client(idx) else { continue };
            match client.submit(&ApiRequest::Stats) {
                Ok(p) => pendings.push((idx, p)),
                Err(_) => self.evict(idx),
            }
        }
        let mut per = Vec::new();
        for (idx, p) in pendings {
            let (status, body) = self.wait_unary(idx, &p, false);
            if status != 200 {
                continue;
            }
            per.push((
                self.registry.name_of(idx),
                self.registry.is_draining(idx),
                body,
            ));
        }
        let fleet =
            merge_stats(&per.iter().map(|(_, _, v)| v.clone()).collect::<Vec<_>>());
        let s = self.registry.stats();
        let body = Value::obj(vec![
            ("fleet", fleet),
            (
                "replicas",
                Value::arr(
                    per.into_iter()
                        .map(|(name, draining, stats)| {
                            Value::obj(vec![
                                ("name", Value::str_of(name)),
                                ("draining", Value::Bool(draining)),
                                ("stats", stats),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "gateway",
                Value::obj(vec![
                    ("routed", Value::num(s.routed as f64)),
                    ("affinity_routes", Value::num(s.affinity_routes as f64)),
                    ("prefix_local", Value::num(s.prefix_local as f64)),
                    ("prefix_fallback", Value::num(s.prefix_fallback as f64)),
                    ("shed", Value::num(s.shed as f64)),
                    (
                        "refused_unavailable",
                        Value::num(s.refused_unavailable as f64),
                    ),
                ]),
            ),
        ]);
        self.reply_json(w, 200, &body, keep, None)
    }

    fn handle_drain(
        &self,
        req: &HttpRequest,
        w: &mut TcpStream,
        keep: bool,
    ) -> Outcome {
        let body = match req.body_object() {
            Ok(b) => b,
            Err(m) => return self.reply_error(w, 400, &ApiError::bad_json(m), keep),
        };
        let Some(name) = body.get("replica").as_str().map(str::to_string)
        else {
            let e = ApiError::bad_field(
                "replica",
                "required: the replica name to drain",
            );
            return self.reply_error(w, 400, &e, keep);
        };
        let deadline_ms = match body.get("deadline_ms") {
            Value::Null => None,
            v => match v.as_i64() {
                Some(n) if n >= 1 => Some(n as u64),
                _ => {
                    let e = ApiError::bad_field(
                        "deadline_ms",
                        "must be an integer >= 1",
                    );
                    return self.reply_error(w, 400, &e, keep);
                }
            },
        };
        let Some(idx) = self.registry.find(&name) else {
            let e = ApiError::replica_unavailable(format!(
                "no replica named '{name}' in this fleet"
            ));
            return self.reply_error(w, 404, &e, keep);
        };
        if !self.registry.is_live(idx) {
            let e = ApiError::replica_unavailable(format!(
                "replica '{name}' was already evicted"
            ));
            return self.reply_error(w, 503, &e, keep);
        }
        // stop routing to it FIRST: in-flight work finishes, new work
        // goes elsewhere (or gets a typed `draining` if pinned here)
        self.registry.set_draining(idx);
        let Some(client) = self.client(idx) else {
            self.evict(idx);
            let e = ApiError::replica_unavailable(format!(
                "replica '{name}' has no live connection"
            ));
            return self.reply_error(w, 503, &e, keep);
        };
        let pending = match client.drain(deadline_ms) {
            Ok(p) => p,
            Err(_) => {
                self.evict(idx);
                let e = ApiError::replica_unavailable(format!(
                    "replica '{name}' connection failed submitting drain"
                ));
                return self.reply_error(w, 503, &e, keep);
            }
        };
        let (status, mut body) = self.wait_unary(idx, &pending, false);
        let code = error_code_of(&body);
        match code.as_deref() {
            Some("replica_unavailable") => {
                // it died mid-drain; eviction already happened in
                // wait_unary — report the typed failure
                self.reply_json(w, status, &body, keep, Some(name))
            }
            Some(_) => self.reply_json(w, status, &body, keep, Some(name)),
            None => {
                let drained = body.get("drained").as_bool() == Some(true);
                if drained {
                    // quiesced: out of the fleet for good. The replica
                    // stops accepting on its own; dropping our slot
                    // closes the mux connection once the last in-flight
                    // handler's Arc goes away.
                    self.evict(idx);
                }
                if let Value::Obj(o) = &mut body {
                    o.insert("replica".into(), Value::str_of(name.clone()));
                }
                self.reply_json(w, 200, &body, keep, Some(name))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping_covers_the_taxonomy() {
        assert_eq!(status_for_code("bad_json"), 400);
        assert_eq!(status_for_code("bad_field"), 400);
        assert_eq!(status_for_code("unknown_session"), 404);
        assert_eq!(status_for_code("unknown_prefix"), 404);
        assert_eq!(status_for_code("session_busy"), 409);
        assert_eq!(status_for_code("prefix_policy_mismatch"), 409);
        assert_eq!(status_for_code("capacity"), 429);
        assert_eq!(status_for_code("too_many_inflight"), 429);
        assert_eq!(status_for_code("cancelled"), 499);
        assert_eq!(status_for_code("draining"), 503);
        assert_eq!(status_for_code("replica_unavailable"), 503);
        assert_eq!(status_for_code("deadline_exceeded"), 504);
        assert_eq!(status_for_code("engine"), 500);
        assert_eq!(status_for_code("internal"), 500);
    }

    #[test]
    fn wire_fields_are_stripped_and_codes_extracted() {
        let v = crate::util::json::parse(
            "{\"v\":3,\"tag\":7,\"done\":true,\"tokens\":[1]}",
        )
        .unwrap();
        let s = strip_wire(v);
        assert_eq!(s.get("v"), &Value::Null);
        assert_eq!(s.get("tag"), &Value::Null);
        assert_eq!(s.get("done"), &Value::Null);
        assert!(s.get("tokens").as_arr().is_some());
        let e = error_body(&ApiError::draining());
        assert_eq!(error_code_of(&e).as_deref(), Some("draining"));
        assert_eq!(error_code_of(&s), None);
    }

    #[test]
    fn stats_merge_sums_counters_and_maxes_watermarks() {
        let a = crate::util::json::parse(
            "{\"requests_completed\":3,\"elapsed_s\":10.0,\
             \"inflight_peak\":4,\"ttft_p95_s\":0.5,\
             \"throughput_tok_s\":100.0,\"nested\":{\"x\":1}}",
        )
        .unwrap();
        let b = crate::util::json::parse(
            "{\"requests_completed\":5,\"elapsed_s\":8.0,\
             \"inflight_peak\":9,\"ttft_p95_s\":0.25,\
             \"throughput_tok_s\":50.0,\"nested\":{\"x\":2}}",
        )
        .unwrap();
        let m = merge_stats(&[a, b]);
        assert_eq!(m.get("requests_completed").as_f64(), Some(8.0));
        assert_eq!(m.get("elapsed_s").as_f64(), Some(10.0));
        assert_eq!(m.get("inflight_peak").as_f64(), Some(9.0));
        assert_eq!(m.get("ttft_p95_s").as_f64(), Some(0.5));
        assert_eq!(m.get("throughput_tok_s").as_f64(), Some(150.0));
        assert_eq!(m.get("nested").get("x").as_f64(), Some(3.0));
    }
}
