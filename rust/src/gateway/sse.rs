//! Server-Sent Events framing for the gateway's streaming responses.
//!
//! Every streaming HTTP operation emits one SSE stream: `token` events
//! while the replica streams, then exactly one terminal `done` (success)
//! or `error` (typed failure) event, after which the connection closes.
//! Event data is always a single-line JSON object — the same shape as
//! the v3 wire frame with the transport fields (`v`, `tag`, `done`)
//! stripped, so SSE consumers and raw-socket consumers read one schema.

use std::io::{self, Write};

use crate::util::json::Value;

/// Terminal event names (data = the final reply / typed error object).
pub const EVENT_DONE: &str = "done";
pub const EVENT_ERROR: &str = "error";
/// Per-token event name (data = `{"token":…,"piece":…}`).
pub const EVENT_TOKEN: &str = "token";

/// Write one SSE event. JSON never contains raw newlines (the codec
/// escapes them), so a single `data:` line always suffices.
pub fn write_event(
    w: &mut impl Write,
    event: &str,
    data: &Value,
) -> io::Result<()> {
    w.write_all(format!("event: {event}\ndata: {data}\n\n").as_bytes())?;
    w.flush()
}

/// One parsed client-side event (tests, demo, bench).
#[derive(Debug, Clone, PartialEq)]
pub struct SseEvent {
    pub event: String,
    pub data: Value,
}

impl SseEvent {
    /// True for the stream-terminating events.
    pub fn is_terminal(&self) -> bool {
        self.event == EVENT_DONE || self.event == EVENT_ERROR
    }
}

/// Parse a full SSE body (blank-line separated events). Lenient client:
/// unknown field lines are skipped, missing `data` yields Null.
pub fn parse_events(body: &str) -> Vec<SseEvent> {
    let mut events = Vec::new();
    let mut name = String::new();
    let mut data: Option<Value> = None;
    for line in body.lines() {
        if line.is_empty() {
            if !name.is_empty() || data.is_some() {
                events.push(SseEvent {
                    event: std::mem::take(&mut name),
                    data: data.take().unwrap_or(Value::Null),
                });
            }
            continue;
        }
        if let Some(v) = line.strip_prefix("event:") {
            name = v.trim().to_string();
        } else if let Some(v) = line.strip_prefix("data:") {
            data = crate::util::json::parse(v.trim()).ok();
        }
    }
    if !name.is_empty() || data.is_some() {
        events.push(SseEvent {
            event: name,
            data: data.unwrap_or(Value::Null),
        });
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_round_trip() {
        let mut buf = Vec::new();
        write_event(
            &mut buf,
            EVENT_TOKEN,
            &Value::obj(vec![
                ("token", Value::num(65.0)),
                ("piece", Value::str_of("A")),
            ]),
        )
        .unwrap();
        write_event(
            &mut buf,
            EVENT_DONE,
            &Value::obj(vec![("tokens", Value::arr(vec![Value::num(65.0)]))]),
        )
        .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("event: token\ndata: {"));
        let events = parse_events(&text);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].event, "token");
        assert_eq!(events[0].data.get("piece").as_str(), Some("A"));
        assert!(!events[0].is_terminal());
        assert_eq!(events[1].event, "done");
        assert!(events[1].is_terminal());
        // error events are terminal too
        let errs = parse_events("event: error\ndata: {\"error\":{}}\n\n");
        assert!(errs[0].is_terminal());
    }
}
