//! Hand-rolled HTTP/1.1 over `std::net` — request parsing and response
//! writing for the gateway. Deliberately minimal: JSON-lines semantics
//! with HTTP framing. Supported: `Content-Length` bodies, keep-alive
//! (the 1.1 default), case-insensitive headers, path + query split.
//! Unsupported (typed 4xx/5xx, never silent): chunked request bodies,
//! HTTP/0.9/2, multipart.

use std::io::{self, BufRead, Read, Write};

use crate::util::json::{self, Value};

/// Request bodies above this are refused with 413 before buffering.
pub const MAX_BODY_BYTES: usize = 4 << 20;
/// A request line / header section above this is malformed.
pub const MAX_HEADER_BYTES: usize = 64 << 10;

/// One parsed request. `path` excludes the query string.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub query: String,
    /// Header names lowercased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

/// Why a request could not be parsed.
#[derive(Debug)]
pub enum HttpParseError {
    /// Clean close / socket error: no response owed.
    Io(io::Error),
    /// Syntactically broken request: answer 400 and close.
    Malformed(String),
    /// Body over [`MAX_BODY_BYTES`]: answer 413 and close.
    BodyTooLarge(usize),
}

impl HttpRequest {
    /// Case-insensitive single-valued header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 keeps the connection unless the client says otherwise.
    pub fn keep_alive(&self) -> bool {
        !self
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }

    /// The body as a JSON object ({} when empty — every route with a
    /// body treats all fields as optional-or-validated downstream).
    pub fn body_object(&self) -> Result<Value, String> {
        if self.body.is_empty() {
            return Ok(Value::obj(vec![]));
        }
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| "request body is not UTF-8".to_string())?;
        let v = json::parse(text.trim())
            .map_err(|e| format!("request body is not valid JSON: {e}"))?;
        match v {
            Value::Obj(_) => Ok(v),
            _ => Err("request body must be a JSON object".into()),
        }
    }
}

/// Read one request off the connection. `Ok(None)` is a clean EOF
/// between requests (keep-alive close).
pub fn read_request(
    reader: &mut impl BufRead,
) -> Result<Option<HttpRequest>, HttpParseError> {
    let Some(request_line) = read_header_line(reader)? else {
        return Ok(None);
    };
    let mut parts = request_line.split_ascii_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m, t, v),
            _ => {
                return Err(HttpParseError::Malformed(format!(
                    "bad request line: {request_line:?}"
                )))
            }
        };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpParseError::Malformed(format!(
            "unsupported protocol version {version:?}"
        )));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut headers = Vec::new();
    let mut header_bytes = 0usize;
    loop {
        let Some(line) = read_header_line(reader)? else {
            return Err(HttpParseError::Malformed(
                "connection closed inside the header section".into(),
            ));
        };
        if line.is_empty() {
            break;
        }
        header_bytes += line.len();
        if header_bytes > MAX_HEADER_BYTES {
            return Err(HttpParseError::Malformed(
                "header section too large".into(),
            ));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpParseError::Malformed(format!(
                "bad header line: {line:?}"
            )));
        };
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }

    let mut req = HttpRequest {
        method: method.to_ascii_uppercase(),
        path,
        query,
        headers,
        body: Vec::new(),
    };
    if req
        .header("transfer-encoding")
        .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
    {
        return Err(HttpParseError::Malformed(
            "chunked request bodies are not supported; send Content-Length"
                .into(),
        ));
    }
    if let Some(len) = req.header("content-length") {
        let len: usize = len.trim().parse().map_err(|_| {
            HttpParseError::Malformed(format!("bad content-length {len:?}"))
        })?;
        if len > MAX_BODY_BYTES {
            return Err(HttpParseError::BodyTooLarge(len));
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).map_err(HttpParseError::Io)?;
        req.body = body;
    }
    Ok(Some(req))
}

/// One CRLF (or bare-LF) terminated line; `None` on EOF before any byte.
fn read_header_line(
    reader: &mut impl BufRead,
) -> Result<Option<String>, HttpParseError> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => Ok(None),
        Ok(_) => Ok(Some(
            line.trim_end_matches('\n').trim_end_matches('\r').to_string(),
        )),
        Err(e) => Err(HttpParseError::Io(e)),
    }
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a JSON response (buffered into one syscall-friendly write).
pub fn write_json(
    w: &mut impl Write,
    status: u16,
    body: &Value,
    keep_alive: bool,
) -> io::Result<()> {
    let payload = format!("{body}\n");
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: {}\r\n\r\n",
        status_text(status),
        payload.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(payload.as_bytes())?;
    w.flush()
}

/// Start an SSE response: headers only, unbounded body, connection
/// closes when the stream ends (no content-length by design).
pub fn write_sse_header(w: &mut impl Write) -> io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\ncontent-type: text/event-stream\r\n\
          cache-control: no-store\r\nconnection: close\r\n\r\n",
    )?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<Option<HttpRequest>, HttpParseError> {
        read_request(&mut BufReader::new(raw.as_bytes()))
    }

    #[test]
    fn parses_request_with_body_and_query() {
        let req = parse(
            "POST /v1/generate?verbose=1 HTTP/1.1\r\nHost: x\r\n\
             Content-Type: application/json\r\nContent-Length: 13\r\n\r\n\
             {\"prompt\":\"y\"}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.query, "verbose=1");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.keep_alive());
        let body = req.body_object().unwrap();
        assert_eq!(body.get("prompt").as_str(), Some("y"));
    }

    #[test]
    fn empty_body_is_empty_object_and_close_is_honoured() {
        let req = parse(
            "GET /v1/health HTTP/1.1\r\nConnection: close\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert!(!req.keep_alive());
        assert_eq!(req.body_object().unwrap(), Value::obj(vec![]));
    }

    #[test]
    fn eof_and_malformed_inputs_are_distinguished() {
        assert!(parse("").unwrap().is_none(), "clean EOF");
        assert!(matches!(
            parse("GARBAGE\r\n\r\n"),
            Err(HttpParseError::Malformed(_))
        ));
        assert!(matches!(
            parse("GET / SPDY/3\r\n\r\n"),
            Err(HttpParseError::Malformed(_))
        ));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n"),
            Err(HttpParseError::Malformed(_))
        ));
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HttpParseError::Malformed(_))
        ));
        assert!(matches!(
            parse(&format!(
                "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                MAX_BODY_BYTES + 1
            )),
            Err(HttpParseError::BodyTooLarge(_))
        ));
        // truncated mid-headers: the line reader sees EOF, not a request
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nHost: x\r\n"),
            Err(HttpParseError::Malformed(_))
        ));
    }

    #[test]
    fn bad_body_json_is_reported() {
        let req = parse(
            "POST / HTTP/1.1\r\nContent-Length: 4\r\n\r\nnope",
        )
        .unwrap()
        .unwrap();
        assert!(req.body_object().is_err());
        let req = parse(
            "POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\n[]",
        )
        .unwrap()
        .unwrap();
        assert!(req.body_object().unwrap_err().contains("object"));
    }

    #[test]
    fn responses_render_status_lines() {
        let mut buf = Vec::new();
        write_json(
            &mut buf,
            429,
            &Value::obj(vec![("ok", Value::Bool(false))]),
            true,
        )
        .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("connection: keep-alive"));
        assert!(text.ends_with("{\"ok\":false}\n"));
        let mut buf = Vec::new();
        write_sse_header(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("text/event-stream"));
    }
}
