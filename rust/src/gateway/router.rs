//! Fleet routing policy: which replica serves which request.
//!
//! [`ReplicaRegistry`] is pure bookkeeping — no sockets, no I/O — so the
//! routing rules are unit-testable in isolation and the gateway's
//! transport layer (one [`crate::server::MuxClient`] per slot) stays a
//! parallel concern. The rules, in order:
//!
//! * **Session affinity** — a session's `SeqCache` lives on exactly one
//!   replica; every turn of a pinned session MUST go there. A draining
//!   pin is refused with the typed `draining` code (no migration: the KV
//!   state cannot move), a dead pin with `replica_unavailable`.
//! * **Prefix placement** — requests naming a `prefix_id` prefer the
//!   replicas already holding the node's pages (registration fans out,
//!   but a replica added later, or one that failed registration, holds
//!   nothing); among holders, least-inflight wins.
//! * **Least-inflight fallback** — everything else goes to the live,
//!   non-draining replica with the fewest requests in flight (ties break
//!   toward fewer pinned sessions, then lower slot index, which also
//!   spreads fresh session opens across the fleet).
//! * **Load shedding** — a routed slot already at `shed_inflight`
//!   requests in flight refuses with a typed 429-mapped `capacity` error
//!   instead of queueing unboundedly.

use std::collections::{BTreeSet, HashMap};
use std::sync::Mutex;

use crate::api::{ApiError, ErrorCode};

/// Where a request wants to land.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteHint<'a> {
    /// No placement constraint: least-inflight live replica.
    Any,
    /// A turn of the gateway session with this id: its pinned replica or
    /// a typed refusal, never a different replica.
    Session(u64),
    /// A request attaching this shared prefix: prefer page residency.
    Prefix(&'a str),
}

/// Why a request could not be routed; maps 1:1 onto typed wire errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    /// No live replica at all.
    NoReplicas,
    /// Every admissible replica (or the session's pin) is draining.
    Draining,
    /// The session id was never opened here (or already closed).
    UnknownSession(u64),
    /// The session's pinned replica died; its KV state died with it.
    ReplicaGone(String),
    /// The routed replica is at its in-flight cap (load shed).
    Overloaded { replica: String, inflight: u64, cap: u64 },
}

impl RouteError {
    pub fn to_api_error(&self) -> ApiError {
        match self {
            RouteError::NoReplicas => ApiError::replica_unavailable(
                "no live replica in the fleet",
            ),
            RouteError::Draining => ApiError::draining(),
            RouteError::UnknownSession(id) => ApiError::unknown_session(*id),
            RouteError::ReplicaGone(name) => ApiError::replica_unavailable(
                format!("replica '{name}' holding this session is gone"),
            ),
            RouteError::Overloaded { replica, inflight, cap } => ApiError::new(
                ErrorCode::Capacity,
                format!(
                    "replica '{replica}' is at capacity \
                     ({inflight}/{cap} requests in flight)"
                ),
            ),
        }
    }
}

/// A session's placement: the slot index plus the replica-local id the
/// gateway translates its own session id to on every turn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionPin {
    pub replica: usize,
    pub remote: u64,
}

/// Point-in-time view of one slot (health/stats endpoints).
#[derive(Debug, Clone)]
pub struct ReplicaView {
    pub name: String,
    pub live: bool,
    pub draining: bool,
    pub inflight: u64,
    pub sessions: usize,
    pub prefixes: Vec<String>,
}

/// Cumulative routing counters (the fleet `stats` gateway section).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Requests successfully routed to a replica.
    pub routed: u64,
    /// Routed via a session pin.
    pub affinity_routes: u64,
    /// Routed via a prefix hint that found the pages resident.
    pub prefix_local: u64,
    /// Prefix hint routed with NO resident replica (placement fallback;
    /// the replica will answer `unknown_prefix` unless it since gained it).
    pub prefix_fallback: u64,
    /// Refused with `capacity` (load shed).
    pub shed: u64,
    /// Refused with `draining` / `replica_unavailable`.
    pub refused_unavailable: u64,
}

struct Slot {
    name: String,
    live: bool,
    draining: bool,
    inflight: u64,
    prefixes: BTreeSet<String>,
}

#[derive(Default)]
struct Inner {
    slots: Vec<Slot>,
    sessions: HashMap<u64, SessionPin>,
    next_session: u64,
    stats: RouterStats,
}

/// The fleet's routing state. Interior-mutable: one registry shared by
/// every gateway connection thread.
pub struct ReplicaRegistry {
    inner: Mutex<Inner>,
    shed_inflight: u64,
}

impl ReplicaRegistry {
    /// `shed_inflight` is the per-replica in-flight cap before requests
    /// shed with `capacity` (0 = never shed).
    pub fn new(shed_inflight: u64) -> Self {
        Self {
            inner: Mutex::new(Inner { next_session: 1, ..Inner::default() }),
            shed_inflight,
        }
    }

    /// Register a replica slot; returns its index.
    pub fn add(&self, name: &str) -> usize {
        let mut g = self.inner.lock().unwrap();
        g.slots.push(Slot {
            name: name.to_string(),
            live: true,
            draining: false,
            inflight: 0,
            prefixes: BTreeSet::new(),
        });
        g.slots.len() - 1
    }

    pub fn find(&self, name: &str) -> Option<usize> {
        self.inner.lock().unwrap().slots.iter().position(|s| s.name == name)
    }

    pub fn name_of(&self, idx: usize) -> String {
        self.inner.lock().unwrap().slots[idx].name.clone()
    }

    /// Take a replica out of rotation for good (transport death or a
    /// completed drain). Its prefix residency is forgotten; session pins
    /// stay so their turns fail with the truthful `replica_unavailable`
    /// rather than a misleading `unknown_session`.
    pub fn evict(&self, idx: usize) {
        let mut g = self.inner.lock().unwrap();
        g.slots[idx].live = false;
        g.slots[idx].prefixes.clear();
    }

    /// Mark a replica draining: pinned sessions and new placements refuse
    /// with `draining` while its in-flight work finishes.
    pub fn set_draining(&self, idx: usize) {
        self.inner.lock().unwrap().slots[idx].draining = true;
    }

    pub fn is_draining(&self, idx: usize) -> bool {
        self.inner.lock().unwrap().slots[idx].draining
    }

    pub fn is_live(&self, idx: usize) -> bool {
        self.inner.lock().unwrap().slots[idx].live
    }

    /// Route one request. On success the chosen slot's in-flight count is
    /// already incremented — callers MUST pair with [`Self::end_request`].
    pub fn route(&self, hint: RouteHint<'_>) -> Result<usize, RouteError> {
        let mut g = self.inner.lock().unwrap();
        let picked = match hint {
            RouteHint::Session(id) => {
                let pin = g
                    .sessions
                    .get(&id)
                    .copied()
                    .ok_or(RouteError::UnknownSession(id))?;
                let slot = &g.slots[pin.replica];
                if !slot.live {
                    g.stats.refused_unavailable += 1;
                    return Err(RouteError::ReplicaGone(slot.name.clone()));
                }
                if slot.draining {
                    g.stats.refused_unavailable += 1;
                    return Err(RouteError::Draining);
                }
                g.stats.affinity_routes += 1;
                pin.replica
            }
            RouteHint::Prefix(name) => {
                let holders: Vec<usize> = admissible(&g.slots)
                    .filter(|&i| g.slots[i].prefixes.contains(name))
                    .collect();
                if holders.is_empty() {
                    // no resident replica: place like Any — the chosen
                    // replica answers `unknown_prefix` itself if the
                    // registration truly never reached it
                    let idx = least_loaded(&g, admissible(&g.slots))
                        .ok_or_else(|| no_candidates(&mut g))?;
                    g.stats.prefix_fallback += 1;
                    idx
                } else {
                    let idx = least_loaded(&g, holders.into_iter())
                        .expect("non-empty holder set");
                    g.stats.prefix_local += 1;
                    idx
                }
            }
            RouteHint::Any => least_loaded(&g, admissible(&g.slots))
                .ok_or_else(|| no_candidates(&mut g))?,
        };
        let slot = &g.slots[picked];
        if self.shed_inflight > 0 && slot.inflight >= self.shed_inflight {
            let err = RouteError::Overloaded {
                replica: slot.name.clone(),
                inflight: slot.inflight,
                cap: self.shed_inflight,
            };
            g.stats.shed += 1;
            return Err(err);
        }
        g.slots[picked].inflight += 1;
        g.stats.routed += 1;
        Ok(picked)
    }

    /// Pair of a successful [`Self::route`]: the request finished (final
    /// frame read or transport failure surfaced).
    pub fn end_request(&self, idx: usize) {
        let mut g = self.inner.lock().unwrap();
        g.slots[idx].inflight = g.slots[idx].inflight.saturating_sub(1);
    }

    /// Pin a freshly opened session; returns the GATEWAY session id the
    /// client uses from now on (replica-local ids collide across the
    /// fleet, so the gateway namespaces them).
    pub fn pin_session(&self, replica: usize, remote: u64) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let id = g.next_session;
        g.next_session += 1;
        g.sessions.insert(id, SessionPin { replica, remote });
        id
    }

    pub fn session_pin(&self, id: u64) -> Option<SessionPin> {
        self.inner.lock().unwrap().sessions.get(&id).copied()
    }

    /// Forget a closed session's pin; returns it for the close fan-in.
    pub fn unpin_session(&self, id: u64) -> Option<SessionPin> {
        self.inner.lock().unwrap().sessions.remove(&id)
    }

    /// Record prefix residency after a successful replica registration.
    pub fn note_prefix(&self, idx: usize, name: &str) {
        self.inner.lock().unwrap().slots[idx].prefixes.insert(name.into());
    }

    /// Forget residency after a release (all replicas).
    pub fn forget_prefix(&self, name: &str) {
        let mut g = self.inner.lock().unwrap();
        for s in &mut g.slots {
            s.prefixes.remove(name);
        }
    }

    /// Slots currently holding the named prefix's pages.
    pub fn prefix_holders(&self, name: &str) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        (0..g.slots.len())
            .filter(|&i| g.slots[i].live && g.slots[i].prefixes.contains(name))
            .collect()
    }

    /// Live, non-draining slots (fan-out targets for registration/stats).
    pub fn admissible_indices(&self) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        admissible(&g.slots).collect()
    }

    /// Live slots including draining ones (observability fan-out).
    pub fn live_indices(&self) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        (0..g.slots.len()).filter(|&i| g.slots[i].live).collect()
    }

    pub fn views(&self) -> Vec<ReplicaView> {
        let g = self.inner.lock().unwrap();
        g.slots
            .iter()
            .enumerate()
            .map(|(i, s)| ReplicaView {
                name: s.name.clone(),
                live: s.live,
                draining: s.draining,
                inflight: s.inflight,
                sessions: g
                    .sessions
                    .values()
                    .filter(|p| p.replica == i)
                    .count(),
                prefixes: s.prefixes.iter().cloned().collect(),
            })
            .collect()
    }

    pub fn stats(&self) -> RouterStats {
        self.inner.lock().unwrap().stats
    }
}

/// Indices admissible for NEW work: live and not draining.
fn admissible(slots: &[Slot]) -> impl Iterator<Item = usize> + '_ {
    (0..slots.len()).filter(|&i| slots[i].live && !slots[i].draining)
}

/// Least-inflight pick; ties break toward fewer pinned sessions, then
/// lower index. The session tiebreak spreads fresh opens (instant ops
/// never overlap long enough for inflight to differentiate slots).
fn least_loaded(g: &Inner, candidates: impl Iterator<Item = usize>) -> Option<usize> {
    candidates.min_by_key(|&i| {
        let pinned = g.sessions.values().filter(|p| p.replica == i).count();
        (g.slots[i].inflight, pinned, i)
    })
}

/// No admissible slot: distinguish "fleet is gone" from "fleet is
/// draining" (clients retry the latter elsewhere/later).
fn no_candidates(g: &mut Inner) -> RouteError {
    g.stats.refused_unavailable += 1;
    if g.slots.iter().any(|s| s.live) {
        RouteError::Draining
    } else {
        RouteError::NoReplicas
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> ReplicaRegistry {
        let reg = ReplicaRegistry::new(0);
        for i in 0..n {
            reg.add(&format!("replica-{i}"));
        }
        reg
    }

    #[test]
    fn session_affinity_survives_interleaved_traffic() {
        let reg = fleet(3);
        // open six sessions; the tiebreak spreads them across the fleet
        let mut pins = Vec::new();
        for remote in 0..6u64 {
            let idx = reg.route(RouteHint::Any).unwrap();
            reg.end_request(idx);
            pins.push((reg.pin_session(idx, 100 + remote), idx));
        }
        let homes: BTreeSet<usize> = pins.iter().map(|&(_, i)| i).collect();
        assert_eq!(homes.len(), 3, "opens spread across all replicas");
        // interleave: anonymous generates churn the inflight counts while
        // session turns keep landing exactly on their pinned replica
        let mut anon_inflight = Vec::new();
        for round in 0..40 {
            let (gw_id, home) = pins[round % pins.len()];
            let idx = reg.route(RouteHint::Session(gw_id)).unwrap();
            assert_eq!(idx, home, "turn {round} must hit the pinned replica");
            let a = reg.route(RouteHint::Any).unwrap();
            anon_inflight.push(a); // held open: skews least-inflight away
            reg.end_request(idx);
            if round % 3 == 0 {
                for a in anon_inflight.drain(..) {
                    reg.end_request(a);
                }
            }
        }
        assert_eq!(reg.stats().affinity_routes, 40);
        // remote translation survives alongside
        let pin = reg.session_pin(pins[0].0).unwrap();
        assert_eq!(pin.remote, 100);
    }

    #[test]
    fn prefix_placement_beats_round_robin_on_residency() {
        let reg = fleet(3);
        // the prefix is resident on replica 1 only (late-joining replicas
        // 0 and 2 missed the registration fan-out)
        reg.note_prefix(1, "sys");
        let n = 30;
        let mut resident_hits = 0;
        for _ in 0..n {
            let idx = reg.route(RouteHint::Prefix("sys")).unwrap();
            reg.end_request(idx);
            if idx == 1 {
                resident_hits += 1;
            }
        }
        assert_eq!(resident_hits, n, "placement always finds the pages");
        // round-robin would have hit residency 1/3 of the time
        let round_robin_hits = n / 3;
        assert!(resident_hits > round_robin_hits);
        assert_eq!(reg.stats().prefix_local, n as u64);
        // with several holders, least-inflight picks among THEM
        reg.note_prefix(2, "sys");
        let busy = reg.route(RouteHint::Prefix("sys")).unwrap();
        let other = reg.route(RouteHint::Prefix("sys")).unwrap();
        assert_ne!(busy, other, "second request avoids the busy holder");
        assert!(busy == 1 || busy == 2);
        assert!(other == 1 || other == 2);
        // no resident replica at all: falls back to Any-placement and
        // counts the miss (the replica itself answers unknown_prefix)
        let idx = reg.route(RouteHint::Prefix("nope")).unwrap();
        assert_eq!(idx, 0, "fallback is plain least-loaded");
        assert_eq!(reg.stats().prefix_fallback, 1);
    }

    #[test]
    fn drain_errors_victims_and_migrates_nothing() {
        let reg = fleet(2);
        let s0 = {
            let idx = reg.route(RouteHint::Any).unwrap();
            reg.end_request(idx);
            assert_eq!(idx, 0);
            reg.pin_session(idx, 7)
        };
        let s1 = {
            let idx = reg.route(RouteHint::Any).unwrap();
            reg.end_request(idx);
            assert_eq!(idx, 1, "session tiebreak spreads the second open");
            reg.pin_session(idx, 7)
        };
        reg.note_prefix(0, "sys");
        reg.note_prefix(1, "sys");
        reg.set_draining(0);
        // the victim's turns are refused with the typed draining code —
        // NOT silently migrated to replica 1 (its KV state is not there)
        let err = reg.route(RouteHint::Session(s0)).unwrap_err();
        assert_eq!(err, RouteError::Draining);
        assert_eq!(
            err.to_api_error().code,
            crate::api::ErrorCode::Draining
        );
        // the survivor's session is untouched
        assert_eq!(reg.route(RouteHint::Session(s1)).unwrap(), 1);
        reg.end_request(1);
        // new work and prefix placement avoid the draining replica
        for _ in 0..5 {
            let idx = reg.route(RouteHint::Any).unwrap();
            assert_eq!(idx, 1);
            reg.end_request(idx);
            let idx = reg.route(RouteHint::Prefix("sys")).unwrap();
            assert_eq!(idx, 1);
            reg.end_request(idx);
        }
        // after eviction the pin reports the replica gone — a truthful
        // transport-level error, not unknown_session
        reg.evict(0);
        let err = reg.route(RouteHint::Session(s0)).unwrap_err();
        assert!(matches!(err, RouteError::ReplicaGone(_)), "{err:?}");
        assert_eq!(
            err.to_api_error().code,
            crate::api::ErrorCode::ReplicaUnavailable
        );
        // close of the survivor unpins normally
        assert_eq!(reg.unpin_session(s1).unwrap().remote, 7);
        assert_eq!(reg.session_pin(s1), None);
    }

    #[test]
    fn whole_fleet_down_vs_draining_is_distinguished() {
        let reg = fleet(2);
        reg.set_draining(0);
        reg.set_draining(1);
        assert_eq!(reg.route(RouteHint::Any).unwrap_err(), RouteError::Draining);
        reg.evict(0);
        reg.evict(1);
        assert_eq!(
            reg.route(RouteHint::Any).unwrap_err(),
            RouteError::NoReplicas
        );
        assert_eq!(
            reg.route(RouteHint::Any).unwrap_err().to_api_error().code,
            crate::api::ErrorCode::ReplicaUnavailable
        );
    }

    #[test]
    fn shedding_caps_per_replica_inflight() {
        let reg = ReplicaRegistry::new(2);
        reg.add("only");
        let a = reg.route(RouteHint::Any).unwrap();
        let b = reg.route(RouteHint::Any).unwrap();
        let err = reg.route(RouteHint::Any).unwrap_err();
        assert!(
            matches!(err, RouteError::Overloaded { inflight: 2, cap: 2, .. }),
            "{err:?}"
        );
        assert_eq!(err.to_api_error().code, crate::api::ErrorCode::Capacity);
        assert_eq!(reg.stats().shed, 1);
        reg.end_request(a);
        reg.end_request(b);
        assert!(reg.route(RouteHint::Any).is_ok(), "capacity freed");
        // sessions shed too: pinned work still queues decode steps
        let s = reg.pin_session(0, 1);
        assert!(reg.route(RouteHint::Session(s)).is_ok(), "one slot free");
        let err = reg.route(RouteHint::Session(s)).unwrap_err();
        assert!(matches!(err, RouteError::Overloaded { .. }), "{err:?}");
    }

    #[test]
    fn views_report_fleet_shape() {
        let reg = fleet(2);
        reg.note_prefix(0, "sys");
        reg.pin_session(1, 9);
        reg.set_draining(1);
        let views = reg.views();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].prefixes, vec!["sys".to_string()]);
        assert!(!views[0].draining);
        assert!(views[1].draining);
        assert_eq!(views[1].sessions, 1);
        assert_eq!(reg.find("replica-1"), Some(1));
        assert_eq!(reg.name_of(0), "replica-0");
    }
}
