//! Test doubles and client helpers for the gateway.
//!
//! Real engines need compiled XLA artifacts to boot, so gateway tests
//! and benches run against [`MockReplica`]: a TCP server that speaks
//! the REAL v3 wire protocol (every line goes through
//! `api::decode_frame`, replies through `api::encode_response_tagged`
//! or hand-built tagged frames) with a fake model behind it. Fidelity
//! points that matter to the gateway:
//!
//! * one sequential worker per replica — capacity scales with replica
//!   count, so fan-out throughput is measurable;
//! * replica-LOCAL session ids — mis-routed turns fail loudly with
//!   `unknown_session` instead of silently succeeding;
//! * faithful drain: admission closes, in-flight work finishes and
//!   streams every frame, prefixes release, then the listener stops
//!   while existing connections stay open;
//! * [`MockReplica::kill`] for transport-failure paths (typed
//!   `replica_unavailable`, gateway eviction).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::api::{self, ApiError, ApiRequest, ApiResponse, GenerateSpec};
use crate::util::json::{self, Value};

use super::sse::{self, SseEvent};

/// Behaviour knobs for one mock replica.
#[derive(Debug, Clone)]
pub struct MockReplicaConfig {
    /// Depth reported by `policies` (must match the gateway's).
    pub n_layers: usize,
    /// Simulated decode time per generated token.
    pub token_time: Duration,
}

impl Default for MockReplicaConfig {
    fn default() -> Self {
        Self { n_layers: 4, token_time: Duration::from_millis(1) }
    }
}

type Job = Box<dyn FnOnce() + Send>;

struct Shared {
    cfg: MockReplicaConfig,
    draining: AtomicBool,
    stopped: AtomicBool,
    /// Generation jobs admitted but not yet finished (drain quiesces on
    /// this).
    inflight: AtomicU64,
    /// Generation requests fully served (placement assertions).
    served: AtomicU64,
    next_session: AtomicU64,
    sessions: Mutex<BTreeMap<u64, usize>>, // id -> turns taken
    prefixes: Mutex<BTreeMap<String, usize>>, // name -> n_tokens
    conns: Mutex<Vec<TcpStream>>,
    jobs: Mutex<mpsc::Sender<Job>>,
}

/// Handle to one running mock replica.
pub struct MockReplica {
    addr: String,
    listener_addr: std::net::SocketAddr,
    shared: Arc<Shared>,
}

impl MockReplica {
    /// Bind on an ephemeral port and start serving.
    pub fn spawn(cfg: MockReplicaConfig) -> Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let listener_addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel::<Job>();
        let shared = Arc::new(Shared {
            cfg,
            draining: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            served: AtomicU64::new(0),
            next_session: AtomicU64::new(1),
            sessions: Mutex::new(BTreeMap::new()),
            prefixes: Mutex::new(BTreeMap::new()),
            conns: Mutex::new(Vec::new()),
            jobs: Mutex::new(tx),
        });
        // THE capacity model: one worker, strictly sequential
        std::thread::spawn(move || {
            while let Ok(job) = rx.recv() {
                job();
            }
        });
        let accept_shared = shared.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                if accept_shared.stopped.load(Ordering::SeqCst) {
                    break; // wakeup connection; stop accepting
                }
                stream.set_nodelay(true).ok();
                if let Ok(clone) = stream.try_clone() {
                    accept_shared.conns.lock().unwrap().push(clone);
                }
                let s = accept_shared.clone();
                std::thread::spawn(move || serve_conn(s, stream));
            }
        });
        Ok(Self {
            addr: listener_addr.to_string(),
            listener_addr,
            shared,
        })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Generation requests this replica finished (fan-out assertions).
    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::SeqCst)
    }

    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// True once the accept loop has stopped (post-drain).
    pub fn is_stopped(&self) -> bool {
        self.shared.stopped.load(Ordering::SeqCst)
    }

    pub fn prefix_names(&self) -> Vec<String> {
        self.shared.prefixes.lock().unwrap().keys().cloned().collect()
    }

    /// Hard-kill every connection AND the listener — simulates a crash.
    /// Clients observe EOF mid-request (typed `replica_unavailable`).
    pub fn kill(&self) {
        self.shared.stopped.store(true, Ordering::SeqCst);
        for c in self.shared.conns.lock().unwrap().iter() {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        let _ = TcpStream::connect(self.listener_addr); // wake accept
    }
}

impl Drop for MockReplica {
    fn drop(&mut self) {
        self.kill();
    }
}

fn tagged_err(e: ApiError, tag: u64) -> Value {
    api::encode_response_tagged(&ApiResponse::Error(e), tag)
}

/// Per-connection reader: decode with the REAL codec, answer each op.
fn serve_conn(shared: Arc<Shared>, stream: TcpStream) {
    let Ok(rstream) = stream.try_clone() else { return };
    let writer = Arc::new(Mutex::new(stream));
    let mut reader = BufReader::new(rstream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        let frame = match api::decode_frame(line.trim(), shared.cfg.n_layers)
        {
            Ok(f) => f,
            Err(de) => {
                let reply = tagged_err(de.error, de.tag.unwrap_or(0));
                write_line(&writer, &reply);
                continue;
            }
        };
        let tag = frame.tag.unwrap_or(0);
        handle_op(&shared, &writer, tag, frame.req);
    }
}

fn write_line(w: &Arc<Mutex<TcpStream>>, v: &Value) {
    let mut w = w.lock().unwrap();
    let _ = writeln!(w, "{v}");
    let _ = w.flush();
}

fn frame(tag: u64, done: bool, fields: Vec<(&str, Value)>) -> Value {
    let mut all = vec![
        ("v", Value::num(3.0)),
        ("tag", Value::num(tag as f64)),
    ];
    all.extend(fields);
    if done {
        all.push(("done", Value::Bool(true)));
    }
    Value::obj(all)
}

fn refuses_while_draining(req: &ApiRequest) -> bool {
    matches!(
        req,
        ApiRequest::Generate(_)
            | ApiRequest::BatchGenerate { .. }
            | ApiRequest::SessionOpen { .. }
            | ApiRequest::SessionAppend { .. }
            | ApiRequest::PrefixRegister { .. }
    )
}

fn handle_op(
    shared: &Arc<Shared>,
    writer: &Arc<Mutex<TcpStream>>,
    tag: u64,
    req: ApiRequest,
) {
    if shared.draining.load(Ordering::SeqCst) && refuses_while_draining(&req)
    {
        write_line(writer, &tagged_err(ApiError::draining(), tag));
        return;
    }
    match req {
        ApiRequest::Ping => {
            write_line(writer, &frame(tag, true, vec![("ok", Value::Bool(true))]));
        }
        ApiRequest::Policies { .. } => {
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        (
                            "n_layers",
                            Value::num(shared.cfg.n_layers as f64),
                        ),
                        ("grid", Value::arr(vec![])),
                        ("specs", Value::arr(vec![])),
                        ("policies", Value::arr(vec![])),
                    ],
                ),
            );
        }
        ApiRequest::Stats => {
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        (
                            "requests_completed",
                            Value::num(
                                shared.served.load(Ordering::SeqCst) as f64,
                            ),
                        ),
                        (
                            "inflight",
                            Value::num(
                                shared.inflight.load(Ordering::SeqCst) as f64,
                            ),
                        ),
                        (
                            "tokens_generated",
                            Value::num(
                                (shared.served.load(Ordering::SeqCst) * 4)
                                    as f64,
                            ),
                        ),
                        ("elapsed_s", Value::num(1.0)),
                        (
                            "sessions_opened",
                            Value::num(
                                shared.sessions.lock().unwrap().len() as f64,
                            ),
                        ),
                    ],
                ),
            );
        }
        ApiRequest::Generate(spec) => {
            enqueue_generation(shared, writer, tag, spec, None);
        }
        ApiRequest::SessionOpen { prefix_id, .. } => {
            if let Some(p) = &prefix_id {
                if !shared.prefixes.lock().unwrap().contains_key(p) {
                    write_line(
                        writer,
                        &tagged_err(
                            ApiError::new(
                                crate::api::ErrorCode::UnknownPrefix,
                                format!("unknown prefix '{p}'"),
                            ),
                            tag,
                        ),
                    );
                    return;
                }
            }
            let id = shared.next_session.fetch_add(1, Ordering::SeqCst);
            shared.sessions.lock().unwrap().insert(id, 0);
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        ("session", Value::num(id as f64)),
                        ("policy", Value::str_of("float")),
                    ],
                ),
            );
        }
        ApiRequest::SessionAppend { session, spec } => {
            {
                let mut sessions = shared.sessions.lock().unwrap();
                let Some(turns) = sessions.get_mut(&session) else {
                    write_line(
                        writer,
                        &tagged_err(ApiError::unknown_session(session), tag),
                    );
                    return;
                };
                *turns += 1;
            }
            enqueue_generation(shared, writer, tag, spec, Some(session));
        }
        ApiRequest::SessionClose { session } => {
            if shared.sessions.lock().unwrap().remove(&session).is_none() {
                write_line(
                    writer,
                    &tagged_err(ApiError::unknown_session(session), tag),
                );
                return;
            }
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        ("session", Value::num(session as f64)),
                        ("closed", Value::Bool(true)),
                    ],
                ),
            );
        }
        ApiRequest::Cancel { target } => {
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        ("target", Value::num(target as f64)),
                        ("cancelled", Value::Bool(false)),
                    ],
                ),
            );
        }
        ApiRequest::PrefixRegister { name, prompt, .. } => {
            let n_tokens = prompt.split_whitespace().count().max(1);
            shared.prefixes.lock().unwrap().insert(name.clone(), n_tokens);
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        ("name", Value::str_of(name)),
                        ("n_tokens", Value::num(n_tokens as f64)),
                        ("policy", Value::str_of("float")),
                    ],
                ),
            );
        }
        ApiRequest::PrefixRelease { name } => {
            if shared.prefixes.lock().unwrap().remove(&name).is_none() {
                write_line(
                    writer,
                    &tagged_err(
                        ApiError::new(
                            crate::api::ErrorCode::UnknownPrefix,
                            format!("unknown prefix '{name}'"),
                        ),
                        tag,
                    ),
                );
                return;
            }
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        ("name", Value::str_of(name)),
                        ("released", Value::Bool(true)),
                    ],
                ),
            );
        }
        ApiRequest::Prefixes => {
            let rows = shared
                .prefixes
                .lock()
                .unwrap()
                .iter()
                .map(|(name, n)| {
                    Value::obj(vec![
                        ("name", Value::str_of(name.clone())),
                        ("n_tokens", Value::num(*n as f64)),
                        ("policy", Value::str_of("float")),
                        ("refcount", Value::num(0.0)),
                    ])
                })
                .collect::<Vec<_>>();
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        ("n", Value::num(rows.len() as f64)),
                        ("prefixes", Value::Arr(rows)),
                    ],
                ),
            );
        }
        ApiRequest::Drain { deadline_ms } => {
            let shared = shared.clone();
            let writer = writer.clone();
            std::thread::spawn(move || {
                run_drain(&shared, &writer, tag, deadline_ms);
            });
        }
        other => {
            write_line(
                writer,
                &tagged_err(
                    ApiError::new(
                        crate::api::ErrorCode::UnknownOp,
                        format!(
                            "mock replica does not implement '{}'",
                            other.op()
                        ),
                    ),
                    tag,
                ),
            );
        }
    }
}

/// Admit a generation: count it in flight, queue it on the single
/// worker. The worker streams (or batches) the tokens with the
/// configured per-token service time.
fn enqueue_generation(
    shared: &Arc<Shared>,
    writer: &Arc<Mutex<TcpStream>>,
    tag: u64,
    spec: GenerateSpec,
    session: Option<u64>,
) {
    if let Some(p) = &spec.prefix_id {
        if !shared.prefixes.lock().unwrap().contains_key(p) {
            write_line(
                writer,
                &tagged_err(
                    ApiError::new(
                        crate::api::ErrorCode::UnknownPrefix,
                        format!("unknown prefix '{p}'"),
                    ),
                    tag,
                ),
            );
            return;
        }
    }
    shared.inflight.fetch_add(1, Ordering::SeqCst);
    let job_shared = shared.clone();
    let writer = writer.clone();
    let job: Job = Box::new(move || {
        let n = spec.n_gen.max(1);
        let mut tokens = Vec::with_capacity(n);
        for i in 0..n {
            std::thread::sleep(job_shared.cfg.token_time);
            let tok = (i % 50) as f64;
            tokens.push(Value::num(tok));
            if spec.stream {
                write_line(
                    &writer,
                    &frame(
                        tag,
                        false,
                        vec![
                            ("token", Value::num(tok)),
                            ("piece", Value::str_of("x")),
                        ],
                    ),
                );
            }
        }
        let mut fields = vec![
            ("tokens", Value::Arr(tokens)),
            ("text", Value::str_of("x".repeat(n))),
            ("n_gen", Value::num(n as f64)),
        ];
        if let Some(s) = session {
            fields.push(("session", Value::num(s as f64)));
        }
        write_line(&writer, &frame(tag, true, fields));
        job_shared.served.fetch_add(1, Ordering::SeqCst);
        job_shared.inflight.fetch_sub(1, Ordering::SeqCst);
    });
    let sent = shared.jobs.lock().unwrap().send(job);
    if sent.is_err() {
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
        write_line(
            writer,
            &tagged_err(
                ApiError::new(
                    crate::api::ErrorCode::Internal,
                    "mock worker is gone",
                ),
                tag,
            ),
        );
    }
}

/// Faithful drain: close admission, wait for the worker to go idle,
/// release prefixes, reply, then stop accepting NEW connections while
/// existing ones stay open (their final frames must remain deliverable).
fn run_drain(
    shared: &Arc<Shared>,
    writer: &Arc<Mutex<TcpStream>>,
    tag: u64,
    deadline_ms: Option<u64>,
) {
    let start = Instant::now();
    shared.draining.store(true, Ordering::SeqCst);
    loop {
        if shared.inflight.load(Ordering::SeqCst) == 0 {
            break;
        }
        if deadline_ms
            .is_some_and(|ms| start.elapsed() >= Duration::from_millis(ms))
        {
            write_line(
                writer,
                &frame(
                    tag,
                    true,
                    vec![
                        ("drained", Value::Bool(false)),
                        (
                            "waited_ms",
                            Value::num(start.elapsed().as_millis() as f64),
                        ),
                        (
                            "inflight",
                            Value::num(
                                shared.inflight.load(Ordering::SeqCst) as f64,
                            ),
                        ),
                        ("released_prefixes", Value::num(0.0)),
                    ],
                ),
            );
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let released = {
        let mut p = shared.prefixes.lock().unwrap();
        let n = p.len();
        p.clear();
        n
    };
    write_line(
        writer,
        &frame(
            tag,
            true,
            vec![
                ("drained", Value::Bool(true)),
                (
                    "waited_ms",
                    Value::num(start.elapsed().as_millis() as f64),
                ),
                ("inflight", Value::num(0.0)),
                ("released_prefixes", Value::num(released as f64)),
            ],
        ),
    );
    shared.stopped.store(true, Ordering::SeqCst);
}

// ----------------------------------------------------------------------
// minimal HTTP client (tests, benches, demo)
// ----------------------------------------------------------------------

/// One-shot HTTP request; returns `(status, parsed JSON body)`.
pub fn http_json(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Value>,
) -> Result<(u16, Value)> {
    let (status, raw) = http_raw(addr, method, path, body)?;
    let v = json::parse(raw.trim())
        .with_context(|| format!("non-JSON body: {raw:?}"))?;
    Ok((status, v))
}

/// One-shot streaming request; returns `(status, parsed SSE events)`.
/// Blocks until the stream's terminal event (the server closes).
pub fn http_sse(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Value>,
) -> Result<(u16, Vec<SseEvent>)> {
    let (status, raw) = http_raw(addr, method, path, body)?;
    Ok((status, sse::parse_events(&raw)))
}

/// Send one `connection: close` request, read the full response.
fn http_raw(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Value>,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting gateway {addr}"))?;
    stream.set_nodelay(true).ok();
    let payload = body.map(|b| b.to_string()).unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nhost: gateway\r\nconnection: close\r\n\
         content-length: {}\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_ascii_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line {status_line:?}"))?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("EOF inside response headers");
        }
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().ok();
            }
        }
    }
    let body = match content_length {
        Some(n) => {
            let mut buf = vec![0u8; n];
            reader.read_exact(&mut buf)?;
            String::from_utf8(buf)?
        }
        None => {
            // SSE: no length, server closes when the stream ends
            let mut buf = String::new();
            reader.read_to_string(&mut buf)?;
            buf
        }
    };
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::MuxClient;

    #[test]
    fn mock_replica_speaks_v3() {
        let replica = MockReplica::spawn(MockReplicaConfig {
            n_layers: 4,
            token_time: Duration::from_micros(100),
        })
        .unwrap();
        let client = MuxClient::connect(replica.addr()).unwrap();
        // policies carries the probe field
        let reply = client
            .submit(&ApiRequest::Policies { policy: None })
            .unwrap()
            .wait_done()
            .unwrap();
        assert_eq!(reply.get("n_layers").as_usize(), Some(4));
        // a streaming generate emits token frames then the final frame
        let pending = client
            .submit(&ApiRequest::Generate(GenerateSpec {
                prompt: "hi".into(),
                n_gen: 3,
                stream: true,
                ..Default::default()
            }))
            .unwrap();
        let mut frames = Vec::new();
        loop {
            let f = pending.recv().unwrap();
            let done = f.get("done").as_bool() == Some(true);
            frames.push(f);
            if done {
                break;
            }
        }
        assert_eq!(frames.len(), 4, "3 token frames + 1 final");
        assert_eq!(
            frames.last().unwrap().get("tokens").as_arr().unwrap().len(),
            3
        );
        assert_eq!(replica.served(), 1);
        // sessions are replica-local and validated
        let open = client
            .submit(&ApiRequest::SessionOpen {
                policy: None,
                prefix_id: None,
            })
            .unwrap()
            .wait_done()
            .unwrap();
        let sid = open.get("session").as_i64().unwrap() as u64;
        let bad = client
            .submit(&ApiRequest::SessionClose { session: sid + 999 })
            .unwrap()
            .wait_done()
            .unwrap();
        assert_eq!(
            bad.get("error").get("code").as_str(),
            Some("unknown_session")
        );
        let ok = client
            .submit(&ApiRequest::SessionClose { session: sid })
            .unwrap()
            .wait_done()
            .unwrap();
        assert_eq!(ok.get("closed").as_bool(), Some(true));
    }

    #[test]
    fn mock_drain_quiesces_and_refuses() {
        let replica =
            MockReplica::spawn(MockReplicaConfig::default()).unwrap();
        let client = MuxClient::connect(replica.addr()).unwrap();
        // park one slow generation, then drain mid-flight
        let gen = client
            .submit(&ApiRequest::Generate(GenerateSpec {
                prompt: "hi".into(),
                n_gen: 30,
                stream: true,
                ..Default::default()
            }))
            .unwrap();
        // wait until the stream is demonstrably in flight
        let first = gen.recv().unwrap();
        assert!(first.get("token").as_i64().is_some());
        let drain = client.drain(None).unwrap();
        let report = drain.wait_done().unwrap();
        assert_eq!(report.get("drained").as_bool(), Some(true));
        // the in-flight stream completed fully first
        let fin = gen.wait_done().unwrap();
        assert_eq!(fin.get("tokens").as_arr().unwrap().len(), 30);
        // admission is closed with the typed code
        let refused = client
            .submit(&ApiRequest::Generate(GenerateSpec {
                prompt: "more".into(),
                n_gen: 1,
                ..Default::default()
            }))
            .unwrap()
            .wait_done()
            .unwrap();
        assert_eq!(
            refused.get("error").get("code").as_str(),
            Some("draining")
        );
        assert!(replica.is_stopped());
    }
}
