//! Typed route table: (method, path pattern) → [`Route`].
//!
//! Patterns are segment-wise with `:param` captures — no regex, no
//! allocation beyond the captured params. Unknown paths are 404; a known
//! path with the wrong method is 405 naming the allowed methods.

/// Every HTTP operation the gateway exposes (see docs/API.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// GET /v1/health — gateway + per-replica liveness.
    Health,
    /// GET /v1/stats — fleet-merged metrics with per-replica breakdown.
    Stats,
    /// GET /v1/replicas — routing table: residency, pins, inflight.
    Replicas,
    /// GET /v1/policies — supported policy surface (any replica).
    Policies,
    /// POST /v1/generate — one generation; SSE when `"stream":true`.
    Generate,
    /// POST /v1/sessions — open a session (optionally onto a prefix).
    SessionOpen,
    /// POST /v1/sessions/:id/turns — one turn; SSE when `"stream":true`.
    SessionTurn,
    /// DELETE /v1/sessions/:id — close.
    SessionClose,
    /// GET /v1/prefixes — fleet-wide residency listing.
    PrefixList,
    /// POST /v1/prefixes — register on every admissible replica.
    PrefixRegister,
    /// DELETE /v1/prefixes/:name — release everywhere it is resident.
    PrefixRelease,
    /// POST /v1/admin/drain — drain one replica out of the fleet.
    Drain,
}

/// A resolved route plus its captured `:param` segments, in path order.
#[derive(Debug, PartialEq, Eq)]
pub struct RouteMatch {
    pub route: Route,
    pub params: Vec<String>,
}

/// Resolution failure, mapped to 404/405 by the gateway.
#[derive(Debug, PartialEq, Eq)]
pub enum RouteFailure {
    NotFound,
    /// The path exists under other methods (the `Allow` header value).
    MethodNotAllowed(&'static str),
}

const TABLE: &[(&str, &str, Route)] = &[
    ("GET", "/v1/health", Route::Health),
    ("GET", "/v1/stats", Route::Stats),
    ("GET", "/v1/replicas", Route::Replicas),
    ("GET", "/v1/policies", Route::Policies),
    ("POST", "/v1/generate", Route::Generate),
    ("POST", "/v1/sessions", Route::SessionOpen),
    ("POST", "/v1/sessions/:id/turns", Route::SessionTurn),
    ("DELETE", "/v1/sessions/:id", Route::SessionClose),
    ("GET", "/v1/prefixes", Route::PrefixList),
    ("POST", "/v1/prefixes", Route::PrefixRegister),
    ("DELETE", "/v1/prefixes/:name", Route::PrefixRelease),
    ("POST", "/v1/admin/drain", Route::Drain),
];

/// Match `path` segment-wise against a pattern, collecting `:captures`.
fn match_pattern(pattern: &str, path: &str) -> Option<Vec<String>> {
    let mut params = Vec::new();
    let mut pat = pattern.split('/').filter(|s| !s.is_empty());
    let mut seg = path.split('/').filter(|s| !s.is_empty());
    loop {
        match (pat.next(), seg.next()) {
            (None, None) => return Some(params),
            (Some(p), Some(s)) if p.starts_with(':') => {
                params.push(s.to_string())
            }
            (Some(p), Some(s)) if p == s => {}
            _ => return None,
        }
    }
}

/// Resolve a request target. 405 replies name every method the path
/// supports so clients can self-correct.
pub fn resolve(method: &str, path: &str) -> Result<RouteMatch, RouteFailure> {
    let mut allowed: Vec<&'static str> = Vec::new();
    for (m, pattern, route) in TABLE {
        if let Some(params) = match_pattern(pattern, path) {
            if method.eq_ignore_ascii_case(m) {
                return Ok(RouteMatch { route: *route, params });
            }
            if !allowed.contains(m) {
                allowed.push(m);
            }
        }
    }
    allowed.sort_unstable();
    match allowed.as_slice() {
        [] => Err(RouteFailure::NotFound),
        // the table's method sets are small and static; name them exactly
        ["GET"] => Err(RouteFailure::MethodNotAllowed("GET")),
        ["POST"] => Err(RouteFailure::MethodNotAllowed("POST")),
        ["DELETE"] => Err(RouteFailure::MethodNotAllowed("DELETE")),
        ["GET", "POST"] => Err(RouteFailure::MethodNotAllowed("GET, POST")),
        _ => Err(RouteFailure::MethodNotAllowed("GET, POST, DELETE")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_routes_resolve() {
        let m = resolve("GET", "/v1/health").unwrap();
        assert_eq!(m.route, Route::Health);
        assert!(m.params.is_empty());
        assert_eq!(resolve("get", "/v1/stats").unwrap().route, Route::Stats);
        assert_eq!(
            resolve("POST", "/v1/generate").unwrap().route,
            Route::Generate
        );
        assert_eq!(
            resolve("POST", "/v1/admin/drain").unwrap().route,
            Route::Drain
        );
        // trailing slash is the same resource
        assert_eq!(
            resolve("GET", "/v1/health/").unwrap().route,
            Route::Health
        );
    }

    #[test]
    fn params_are_captured_in_order() {
        let m = resolve("POST", "/v1/sessions/42/turns").unwrap();
        assert_eq!(m.route, Route::SessionTurn);
        assert_eq!(m.params, vec!["42".to_string()]);
        let m = resolve("DELETE", "/v1/sessions/7").unwrap();
        assert_eq!(m.route, Route::SessionClose);
        assert_eq!(m.params, vec!["7".to_string()]);
        let m = resolve("DELETE", "/v1/prefixes/sys-v2").unwrap();
        assert_eq!(m.route, Route::PrefixRelease);
        assert_eq!(m.params, vec!["sys-v2".to_string()]);
    }

    #[test]
    fn not_found_vs_method_not_allowed() {
        assert_eq!(resolve("GET", "/nope").unwrap_err(), RouteFailure::NotFound);
        assert_eq!(
            resolve("GET", "/v1/sessions/1/turns/extra").unwrap_err(),
            RouteFailure::NotFound
        );
        assert_eq!(
            resolve("DELETE", "/v1/generate").unwrap_err(),
            RouteFailure::MethodNotAllowed("POST")
        );
        // /v1/prefixes supports GET and POST
        assert_eq!(
            resolve("DELETE", "/v1/prefixes").unwrap_err(),
            RouteFailure::MethodNotAllowed("GET, POST")
        );
        assert_eq!(
            resolve("POST", "/v1/health").unwrap_err(),
            RouteFailure::MethodNotAllowed("GET")
        );
    }
}
