//! Cross-language golden tests: the Rust quantization kernels and workload
//! generators must match the Python reference bit-for-bit / byte-for-byte.

mod common;

use asymkv::quant::kernels::{self, KernelMode};
use asymkv::util::json::{base64_decode, Value};
use asymkv::util::rng::SplitMix;
use asymkv::workload;

/// Every kernel tier must match the Python reference — the golden vectors
/// go through the dispatch layer with each mode pinned. The simd/fused
/// tiers share fold routes with wordpack on the K side and use the
/// vectorized sweeps on the V side; all are byte-identical by property
/// test, and the goldens pin that against the independent Python reference.
const MODES: [KernelMode; 4] = [
    KernelMode::Scalar,
    KernelMode::Wordpack,
    KernelMode::Simd,
    KernelMode::Fused,
];

fn f32s(v: &Value) -> Vec<f32> {
    v.f32_vec().expect("float array")
}

#[test]
fn fold_k_matches_python_bit_exact() {
    let Some(g) = common::golden("tiny") else { return };
    for mode in MODES {
        for bits in [1u8, 2, 4] {
            let case = g.get(&format!("fold_k_bits{bits}"));
            let input = f32s(case.get("input"));
            let shape = case.get("shape").usize_vec().unwrap(); // [1, 2, G, Dh]
            let (b, h, gg, dh) = (shape[0], shape[1], shape[2], shape[3]);
            let want_packed = base64_decode(case.get("packed").as_str().unwrap()).unwrap();
            let want_scale = f32s(case.get("scale"));
            let want_zero = f32s(case.get("zero"));
            let rows_pk = kernels::packed_len(gg, bits);
            let mut got_packed = vec![0u8; b * h * rows_pk * dh];
            let mut got_scale = vec![0f32; b * h * dh];
            let mut got_zero = vec![0f32; b * h * dh];
            for bh in 0..b * h {
                let kg = &input[bh * gg * dh..(bh + 1) * gg * dh];
                let mut params =
                    vec![kernels::GroupParams { scale: 0.0, zero: 0.0 }; dh];
                kernels::fold_k_group_with(
                    mode, kg, gg, dh, bits,
                    &mut got_packed[bh * rows_pk * dh..(bh + 1) * rows_pk * dh],
                    &mut params,
                );
                for d in 0..dh {
                    got_scale[bh * dh + d] = params[d].scale;
                    got_zero[bh * dh + d] = params[d].zero;
                }
            }
            assert_eq!(got_packed, want_packed,
                       "K packed bytes diverge at {bits}b ({mode:?})");
            assert_eq!(got_scale, want_scale, "K scales diverge at {bits}b ({mode:?})");
            assert_eq!(got_zero, want_zero, "K zeros diverge at {bits}b ({mode:?})");
        }
    }
}

#[test]
fn fold_v_matches_python_bit_exact() {
    let Some(g) = common::golden("tiny") else { return };
    for mode in MODES {
        for bits in [1u8, 2, 4] {
            let case = g.get(&format!("fold_v_bits{bits}"));
            let input = f32s(case.get("input"));
            let shape = case.get("shape").usize_vec().unwrap();
            let (b, h, gg, dh) = (shape[0], shape[1], shape[2], shape[3]);
            let g2 = 32usize.min(dh);
            let dg = dh / g2;
            let want_packed = base64_decode(case.get("packed").as_str().unwrap()).unwrap();
            let want_scale = f32s(case.get("scale"));
            let bpt = kernels::packed_len(dh, bits);
            let mut got_packed = vec![0u8; b * h * gg * bpt];
            let mut got_scale = vec![0f32; b * h * gg * dg];
            for bh in 0..b * h {
                let vg = &input[bh * gg * dh..(bh + 1) * gg * dh];
                let mut params =
                    vec![kernels::GroupParams { scale: 0.0, zero: 0.0 }; gg * dg];
                kernels::fold_v_group_with(
                    mode, vg, gg, dh, g2, bits,
                    &mut got_packed[bh * gg * bpt..(bh + 1) * gg * bpt],
                    &mut params,
                );
                for i in 0..gg * dg {
                    got_scale[bh * gg * dg + i] = params[i].scale;
                }
            }
            assert_eq!(got_packed, want_packed,
                       "V packed bytes diverge at {bits}b ({mode:?})");
            assert_eq!(got_scale, want_scale, "V scales diverge at {bits}b ({mode:?})");
        }
    }
}

#[test]
fn splitmix_stream_matches_python() {
    let Some(g) = common::golden("tiny") else { return };
    let want: Vec<u64> = g
        .get("splitmix_seed7_first8")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u64)
        .collect();
    let mut rng = SplitMix::new(7);
    let got: Vec<u64> = (0..8).map(|_| rng.next_u64() % (1 << 32)).collect();
    assert_eq!(got, want);
}

#[test]
fn corpus_document_matches_python_byte_exact() {
    let Some(g) = common::golden("tiny") else { return };
    let want = base64_decode(g.get("document_seed123_len256").as_str().unwrap())
        .unwrap();
    let got = workload::gen_document(&mut SplitMix::new(123), 256);
    assert_eq!(
        String::from_utf8_lossy(&got),
        String::from_utf8_lossy(&want),
        "corpus generators diverged — update the rust mirror of data.py"
    );
}

#[test]
fn recall_task_matches_python() {
    let Some(g) = common::golden("tiny") else { return };
    let case = g.get("recall_seed99");
    let want_prompt = base64_decode(case.get("prompt").as_str().unwrap()).unwrap();
    let want_answer = case.get("answer").as_str().unwrap();
    let ep = asymkv::workload::tasks::recall_episode(&mut SplitMix::new(99), 5);
    assert_eq!(String::from_utf8_lossy(&ep.prompt),
               String::from_utf8_lossy(&want_prompt));
    assert_eq!(ep.answer, want_answer);
}

#[test]
fn needle_task_matches_python() {
    let Some(g) = common::golden("tiny") else { return };
    let case = g.get("needle_seed77");
    let want_prompt = base64_decode(case.get("prompt").as_str().unwrap()).unwrap();
    let ep = asymkv::workload::tasks::needle_episode(&mut SplitMix::new(77), 30, 0.5);
    assert_eq!(String::from_utf8_lossy(&ep.prompt),
               String::from_utf8_lossy(&want_prompt));
    assert_eq!(ep.answer, case.get("answer").as_str().unwrap());
}
