//! Gateway integration tests over a mock replica fleet.
//!
//! Real engines need compiled artifacts, so these tests run the REAL
//! gateway (HTTP parsing, routing, SSE relay, drain orchestration,
//! MuxClient transport) against `gateway::testing::MockReplica` — a TCP
//! server speaking the genuine v3 codec with a fake model behind it.
//! What is mocked is token generation; every wire byte is production
//! code.

use std::sync::Arc;
use std::time::Duration;

use asymkv::gateway::testing::{
    http_json, http_sse, MockReplica, MockReplicaConfig,
};
use asymkv::gateway::{Gateway, GatewayConfig};
use asymkv::util::json::Value;

/// Boot `n` mock replicas and a gateway over them; returns the fleet,
/// the gateway handle, and its HTTP address.
fn boot_fleet(
    n: usize,
    token_time: Duration,
) -> (Vec<MockReplica>, Arc<Gateway>, String) {
    let replicas: Vec<MockReplica> = (0..n)
        .map(|_| {
            MockReplica::spawn(MockReplicaConfig { n_layers: 4, token_time })
                .unwrap()
        })
        .collect();
    let addrs: Vec<String> =
        replicas.iter().map(|r| r.addr().to_string()).collect();
    let gw = Arc::new(
        Gateway::bind("127.0.0.1:0", &addrs, GatewayConfig::default())
            .unwrap(),
    );
    let addr = gw.local_addr();
    let serve = gw.clone();
    std::thread::spawn(move || {
        let _ = serve.serve();
    });
    (replicas, gw, addr)
}

fn gen_body(prompt: &str, n_gen: usize, stream: bool) -> Value {
    Value::obj(vec![
        ("prompt", Value::str_of(prompt)),
        ("n_gen", Value::num(n_gen as f64)),
        ("stream", Value::Bool(stream)),
    ])
}

fn code_of(v: &Value) -> Option<&str> {
    v.get("error").get("code").as_str()
}

#[test]
fn routes_validation_and_sse_streaming() {
    let (_replicas, gw, addr) =
        boot_fleet(2, Duration::from_micros(200));

    // health reports the whole fleet live
    let (status, body) = http_json(&addr, "GET", "/v1/health", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(body.get("ok").as_bool(), Some(true));
    assert_eq!(body.get("replicas").as_arr().unwrap().len(), 2);

    // unary generate: plain JSON reply, wire fields stripped
    let (status, body) = http_json(
        &addr,
        "POST",
        "/v1/generate",
        Some(&gen_body("hello", 4, false)),
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body.get("tokens").as_arr().unwrap().len(), 4);
    assert_eq!(body.get("v"), &Value::Null);
    assert_eq!(body.get("tag"), &Value::Null);
    assert_eq!(body.get("done"), &Value::Null);

    // streaming generate: token events then exactly one terminal done
    let (status, events) = http_sse(
        &addr,
        "POST",
        "/v1/generate",
        Some(&gen_body("hello", 6, true)),
    )
    .unwrap();
    assert_eq!(status, 200);
    let tokens = events.iter().filter(|e| e.event == "token").count();
    assert_eq!(tokens, 6);
    let last = events.last().unwrap();
    assert_eq!(last.event, "done");
    assert_eq!(last.data.get("tokens").as_arr().unwrap().len(), 6);

    // validation is the replicas' own strict decoder: typed, 400-class
    let (status, body) = http_json(
        &addr,
        "POST",
        "/v1/generate",
        Some(&Value::obj(vec![
            ("prompt", Value::str_of("x")),
            ("n_gen", Value::num(1.0)),
            ("bogus_field", Value::num(1.0)),
        ])),
    )
    .unwrap();
    assert_eq!(status, 400);
    assert_eq!(code_of(&body), Some("bad_field"));

    // wire-framing fields are refused, not silently overwritten
    let (status, body) = http_json(
        &addr,
        "POST",
        "/v1/generate",
        Some(&Value::obj(vec![
            ("prompt", Value::str_of("x")),
            ("n_gen", Value::num(1.0)),
            ("tag", Value::num(7.0)),
        ])),
    )
    .unwrap();
    assert_eq!(status, 400);
    assert_eq!(code_of(&body), Some("bad_field"));

    // unknown path → 404; known path, wrong method → 405
    let (status, body) = http_json(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    assert_eq!(code_of(&body), Some("unknown_op"));
    let (status, _body) =
        http_json(&addr, "DELETE", "/v1/generate", None).unwrap();
    assert_eq!(status, 405);

    // fleet stats: merged view + per-replica breakdown + router counters
    let (status, body) = http_json(&addr, "GET", "/v1/stats", None).unwrap();
    assert_eq!(status, 200);
    assert!(
        body.get("fleet").get("requests_completed").as_f64().unwrap() >= 2.0
    );
    assert_eq!(body.get("replicas").as_arr().unwrap().len(), 2);
    assert!(body.get("gateway").get("routed").as_f64().unwrap() >= 2.0);

    gw.request_stop();
}

#[test]
fn session_affinity_and_gateway_namespaced_ids() {
    let (replicas, gw, addr) = boot_fleet(2, Duration::from_micros(200));

    // open four sessions; the router spreads them across the fleet
    let mut ids = Vec::new();
    for _ in 0..4 {
        let (status, body) =
            http_json(&addr, "POST", "/v1/sessions", Some(&Value::obj(vec![])))
                .unwrap();
        assert_eq!(status, 200, "{body}");
        ids.push(body.get("session").as_i64().unwrap() as u64);
        assert!(body.get("replica").as_str().is_some());
    }
    // gateway ids are namespaced and unique even though each replica
    // numbers its own sessions from 1
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), 4, "gateway session ids collide: {ids:?}");

    // interleave turns across all sessions, repeatedly: every turn must
    // land on the session's pinned replica. The mock replicas enforce
    // this for us — a mis-routed turn answers `unknown_session`.
    for round in 0..3 {
        for &id in &ids {
            let (status, body) = http_json(
                &addr,
                "POST",
                &format!("/v1/sessions/{id}/turns"),
                Some(&gen_body("turn", 2, false)),
            )
            .unwrap();
            assert_eq!(status, 200, "round {round}: {body}");
            // the reply echoes the GATEWAY id, not the replica-local one
            assert_eq!(body.get("session").as_i64(), Some(id as i64));
        }
    }
    let (_, body) = http_json(&addr, "GET", "/v1/replicas", None).unwrap();
    let affinity =
        body.get("router").get("affinity_routes").as_f64().unwrap();
    assert_eq!(affinity, 12.0, "every turn routed by affinity");
    // both replicas actually served turns (sessions were spread)
    assert!(replicas.iter().all(|r| r.served() > 0));

    // close, then a turn on the closed id is a typed 404
    let (status, body) = http_json(
        &addr,
        "DELETE",
        &format!("/v1/sessions/{}", ids[0]),
        None,
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body.get("session").as_i64(), Some(ids[0] as i64));
    let (status, body) = http_json(
        &addr,
        "POST",
        &format!("/v1/sessions/{}/turns", ids[0]),
        Some(&gen_body("turn", 1, false)),
    )
    .unwrap();
    assert_eq!(status, 404);
    assert_eq!(code_of(&body), Some("unknown_session"));

    gw.request_stop();
}

#[test]
fn prefix_registration_fans_out_and_routes_by_residency() {
    let (replicas, gw, addr) = boot_fleet(2, Duration::from_micros(200));

    // register once at the gateway → resident on EVERY replica
    let (status, body) = http_json(
        &addr,
        "POST",
        "/v1/prefixes",
        Some(&Value::obj(vec![
            ("name", Value::str_of("sys")),
            ("prompt", Value::str_of("you are a helpful assistant")),
        ])),
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body.get("replicas").as_arr().unwrap().len(), 2);
    assert!(replicas
        .iter()
        .all(|r| r.prefix_names() == vec!["sys".to_string()]));

    // the fleet listing shows it per replica
    let (_, body) = http_json(&addr, "GET", "/v1/prefixes", None).unwrap();
    assert_eq!(body.get("n").as_usize(), Some(2));

    // prefix-hinted generates route to holders (both replicas hold it;
    // concurrency forces the least-inflight split to use both)
    let handles: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                http_sse(
                    &addr,
                    "POST",
                    "/v1/generate",
                    Some(&Value::obj(vec![
                        ("prompt", Value::str_of("q")),
                        ("n_gen", Value::num(8.0)),
                        ("stream", Value::Bool(true)),
                        ("prefix_id", Value::str_of("sys")),
                    ])),
                )
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        let (status, events) = h.join().unwrap();
        assert_eq!(status, 200);
        assert_eq!(events.last().unwrap().event, "done");
    }
    let (_, body) = http_json(&addr, "GET", "/v1/replicas", None).unwrap();
    assert_eq!(
        body.get("router").get("prefix_local").as_f64(),
        Some(6.0),
        "every prefix generate hit a resident replica"
    );
    assert!(
        replicas.iter().all(|r| r.served() > 0),
        "concurrent prefix traffic used both holders: {:?}",
        replicas.iter().map(|r| r.served()).collect::<Vec<_>>()
    );

    // a generate naming an unknown prefix is a typed 404
    let (status, body) = http_json(
        &addr,
        "POST",
        "/v1/generate",
        Some(&Value::obj(vec![
            ("prompt", Value::str_of("q")),
            ("n_gen", Value::num(1.0)),
            ("prefix_id", Value::str_of("nope")),
        ])),
    )
    .unwrap();
    assert_eq!(status, 404, "{body}");
    assert_eq!(code_of(&body), Some("unknown_prefix"));

    // release everywhere; a second release is a typed 404
    let (status, body) =
        http_json(&addr, "DELETE", "/v1/prefixes/sys", None).unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(body.get("released").as_arr().unwrap().len(), 2);
    assert!(replicas.iter().all(|r| r.prefix_names().is_empty()));
    let (status, body) =
        http_json(&addr, "DELETE", "/v1/prefixes/sys", None).unwrap();
    assert_eq!(status, 404);
    assert_eq!(code_of(&body), Some("unknown_prefix"));

    gw.request_stop();
}

/// The drain acceptance scenario end to end: a replica drains while one
/// of its streams is mid-flight. The stream must deliver EVERY frame
/// (zero dropped), new work on the victim's sessions gets the typed
/// `draining` error while the drain is pending, unpinned work routes to
/// the survivor, and afterwards the drained replica has stopped with
/// its prefixes released.
#[test]
fn drain_mid_stream_finishes_victims_and_sheds_new_work() {
    let (replicas, gw, addr) = boot_fleet(2, Duration::from_millis(4));

    // a prefix resident everywhere (the drain must release it)
    let (status, _) = http_json(
        &addr,
        "POST",
        "/v1/prefixes",
        Some(&Value::obj(vec![
            ("name", Value::str_of("sys")),
            ("prompt", Value::str_of("shared context")),
        ])),
    )
    .unwrap();
    assert_eq!(status, 200);

    // a session; its pin is the drain victim
    let (_, body) =
        http_json(&addr, "POST", "/v1/sessions", Some(&Value::obj(vec![])))
            .unwrap();
    let sid = body.get("session").as_i64().unwrap();
    let victim = body.get("replica").as_str().unwrap().to_string();
    let victim_idx = replicas
        .iter()
        .position(|r| r.addr() == victim)
        .expect("replica name is its address");

    // start a LONG streaming turn on the pinned replica (~160ms)
    let stream_addr = addr.clone();
    let streamer = std::thread::spawn(move || {
        http_sse(
            &stream_addr,
            "POST",
            &format!("/v1/sessions/{sid}/turns"),
            Some(&gen_body("long turn", 40, true)),
        )
        .unwrap()
    });
    std::thread::sleep(Duration::from_millis(30)); // stream is in flight

    // drain the victim in the background (it blocks on the stream)
    let drain_addr = addr.clone();
    let victim_name = victim.clone();
    let drainer = std::thread::spawn(move || {
        http_json(
            &drain_addr,
            "POST",
            "/v1/admin/drain",
            Some(&Value::obj(vec![(
                "replica",
                Value::str_of(victim_name),
            )])),
        )
        .unwrap()
    });
    std::thread::sleep(Duration::from_millis(30)); // drain is pending

    // the victim's sessions are refused with the TYPED code while the
    // in-flight stream keeps running — nothing is migrated
    let (status, body) = http_json(
        &addr,
        "POST",
        &format!("/v1/sessions/{sid}/turns"),
        Some(&gen_body("rejected", 1, false)),
    )
    .unwrap();
    assert_eq!(status, 503, "{body}");
    assert_eq!(code_of(&body), Some("draining"));

    // unpinned work routes to the survivor and succeeds mid-drain
    let (status, body) = http_json(
        &addr,
        "POST",
        "/v1/generate",
        Some(&gen_body("elsewhere", 2, false)),
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");

    // the drain completes only after the stream quiesces, successfully
    let (status, report) = drainer.join().unwrap();
    assert_eq!(status, 200, "{report}");
    assert_eq!(report.get("drained").as_bool(), Some(true));
    assert_eq!(report.get("replica").as_str(), Some(victim.as_str()));
    assert!(report.get("released_prefixes").as_usize().unwrap() >= 1);

    // ZERO dropped frames: all 40 tokens and the terminal done arrived
    let (status, events) = streamer.join().unwrap();
    assert_eq!(status, 200);
    assert_eq!(
        events.iter().filter(|e| e.event == "token").count(),
        40,
        "the drained replica dropped in-flight stream frames"
    );
    assert_eq!(events.last().unwrap().event, "done");

    // the drained replica stopped accepting and released its prefixes
    assert!(replicas[victim_idx].is_stopped());
    assert!(replicas[victim_idx].prefix_names().is_empty());

    // it is out of the fleet: health shows one live replica, the dead
    // session pin is a typed replica_unavailable now
    let (_, body) = http_json(&addr, "GET", "/v1/health", None).unwrap();
    let live = body
        .get("replicas")
        .as_arr()
        .unwrap()
        .iter()
        .filter(|r| r.get("live").as_bool() == Some(true))
        .count();
    assert_eq!(live, 1);
    let (status, body) = http_json(
        &addr,
        "POST",
        &format!("/v1/sessions/{sid}/turns"),
        Some(&gen_body("gone", 1, false)),
    )
    .unwrap();
    assert_eq!(status, 503);
    assert_eq!(code_of(&body), Some("replica_unavailable"));

    // the survivor still takes fleet traffic
    let (status, _) = http_json(
        &addr,
        "POST",
        "/v1/generate",
        Some(&gen_body("after", 2, false)),
    )
    .unwrap();
    assert_eq!(status, 200);

    gw.request_stop();
}

/// Transport-failure robustness (MuxClient satellite): a crashed
/// replica surfaces as typed `replica_unavailable` — mid-stream as a
/// terminal SSE error event, and placement-routed requests fail over to
/// a survivor after eviction.
#[test]
fn replica_crash_is_typed_and_evicts() {
    // single replica: a mid-stream crash must end the SSE stream with
    // the typed error, not a hang or a silent close
    let (replicas, gw, addr) = boot_fleet(1, Duration::from_millis(4));
    let stream_addr = addr.clone();
    let streamer = std::thread::spawn(move || {
        http_sse(
            &stream_addr,
            "POST",
            "/v1/generate",
            Some(&gen_body("doomed", 50, true)),
        )
        .unwrap()
    });
    std::thread::sleep(Duration::from_millis(40));
    replicas[0].kill();
    let (status, events) = streamer.join().unwrap();
    assert_eq!(status, 200); // SSE headers were already sent
    let last = events.last().unwrap();
    assert_eq!(last.event, "error", "events: {events:?}");
    assert_eq!(code_of(&last.data), Some("replica_unavailable"));
    // the fleet is empty now — typed 503, not a connect hang
    let (status, body) = http_json(
        &addr,
        "POST",
        "/v1/generate",
        Some(&gen_body("x", 1, false)),
    )
    .unwrap();
    assert_eq!(status, 503, "{body}");
    assert_eq!(code_of(&body), Some("replica_unavailable"));
    gw.request_stop();

    // two replicas: kill one while idle; unpinned traffic fails over
    let (replicas, gw, addr) = boot_fleet(2, Duration::from_micros(200));
    replicas[0].kill();
    for _ in 0..3 {
        let (status, body) = http_json(
            &addr,
            "POST",
            "/v1/generate",
            Some(&gen_body("failover", 2, false)),
        )
        .unwrap();
        assert_eq!(status, 200, "{body}");
    }
    assert_eq!(replicas[1].served(), 3);
    let (_, body) = http_json(&addr, "GET", "/v1/health", None).unwrap();
    let live = body
        .get("replicas")
        .as_arr()
        .unwrap()
        .iter()
        .filter(|r| r.get("live").as_bool() == Some(true))
        .count();
    assert_eq!(live, 1);
    gw.request_stop();
}

/// Chaos under load (the trace harness's replica-kill scenario, pinned
/// as a deterministic test): with many streams in flight across a
/// 2-replica fleet, hard-killing one replica must (a) end every stream
/// it was carrying with the typed `replica_unavailable` terminal event —
/// no hangs, no silent closes — (b) leave the survivor's streams intact,
/// and (c) route all subsequent traffic to the survivor.
#[test]
fn replica_kill_under_load_types_failures_and_survivor_serves() {
    let (replicas, gw, addr) = boot_fleet(2, Duration::from_millis(4));

    // 8 concurrent 40-token streams: ~160 ms of sequential work per
    // replica, so the kill at 60 ms lands mid-flight with queued work
    let streamers: Vec<_> = (0..8)
        .map(|i| {
            let a = addr.clone();
            std::thread::spawn(move || {
                http_sse(
                    &a,
                    "POST",
                    "/v1/generate",
                    Some(&gen_body(&format!("load {i}"), 40, true)),
                )
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(60));
    replicas[0].kill();

    let mut completed = 0usize;
    let mut unavailable = 0usize;
    for s in streamers {
        // every stream terminates — a hang here fails the test timeout
        let (_, events) = s.join().unwrap().unwrap();
        match events.last() {
            Some(e) if e.event == "done" => completed += 1,
            Some(e) if e.event == "error" => {
                assert_eq!(
                    code_of(&e.data),
                    Some("replica_unavailable"),
                    "mid-kill stream must fail typed: {events:?}"
                );
                unavailable += 1;
            }
            other => panic!("stream ended without a terminal event: {other:?}"),
        }
    }
    assert!(unavailable >= 1, "the kill hit no in-flight stream");
    assert!(completed >= 1, "the survivor completed nothing under load");

    // the dead replica is out of rotation: new work lands on the survivor
    let before = replicas[1].served();
    for _ in 0..3 {
        let (status, body) = http_json(
            &addr,
            "POST",
            "/v1/generate",
            Some(&gen_body("after the kill", 2, false)),
        )
        .unwrap();
        assert_eq!(status, 200, "{body}");
    }
    assert_eq!(replicas[1].served(), before + 3);
    gw.request_stop();
}
