//! End-to-end engine tests over the tiny AOT artifacts: the Rust engine
//! (PJRT runtime + packed caches + fold protocol) must reproduce the
//! Python float forward, degrade gracefully under quantization, and keep
//! its memory accounting consistent.

mod common;

use asymkv::engine::SamplingParams;
use asymkv::model::ByteTokenizer;
use asymkv::quant::QuantPolicy;
use asymkv::util::json::base64_decode;

/// The anchor test: greedy decode under the FLOAT policy must reproduce
/// the Python-side logits trace (same weights, same math, different
/// execution path: chunked prefill + cache decode vs full recompute).
#[test]
fn float_decode_matches_python_trace() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let g = common::golden("tiny").unwrap();
    let trace = g.get("decode_trace");
    let prompt = base64_decode(trace.get("prompt").as_str().unwrap()).unwrap();
    let want_tokens: Vec<i32> = trace
        .get("generated")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    let want_logits = trace.get("logits").as_arr().unwrap();

    let tok = ByteTokenizer;
    let policy = QuantPolicy::float32(engine.manifest().n_layers);
    let id = engine.create_seq(&policy).unwrap();
    let mut logits = engine
        .prefill(&[id], &[tok.encode(&prompt)])
        .unwrap()
        .remove(0);

    for (step, want_tok) in want_tokens.iter().enumerate() {
        let want = want_logits[step].f32_vec().unwrap();
        let max_abs = want.iter().fold(0f32, |a, &b| a.max(b.abs()));
        for (i, (&got, &w)) in logits.iter().zip(&want).enumerate() {
            assert!(
                (got - w).abs() < 2e-3 * max_abs.max(1.0),
                "step {step} logit {i}: rust {got} vs python {w}"
            );
        }
        let got_tok = asymkv::engine::argmax(&logits);
        assert_eq!(got_tok, *want_tok, "argmax diverged at step {step}");
        logits = engine.decode(&[id], &[got_tok]).unwrap().remove(0);
    }
    engine.free_seq(id).unwrap();
}

#[test]
fn all_grid_policies_run_and_stay_finite() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let mut rng = asymkv::util::rng::SplitMix::new(3);
    let doc = asymkv::workload::gen_document(&mut rng, 100);
    let tok = ByteTokenizer;
    for policy in [
        QuantPolicy::float32(n),
        QuantPolicy::kivi(n, 1),
        QuantPolicy::kivi(n, 2),
        QuantPolicy::asymkv21(n, n / 2, 0),
        QuantPolicy::asymkv21(n, 0, n / 2),
        QuantPolicy::k_only(n, 2),
        QuantPolicy::v_only(n, 1),
    ] {
        let id = engine.create_seq(&policy).unwrap();
        let out = engine
            .generate(&[id], &[tok.encode(&doc)], 4,
                      &SamplingParams::greedy(), 0)
            .unwrap();
        assert_eq!(out[0].len(), 4, "{policy}");
        let logits = engine.decode(&[id], &[out[0][3]]).unwrap();
        assert!(
            logits[0].iter().all(|x| x.is_finite()),
            "non-finite logits under {policy}"
        );
        engine.free_seq(id).unwrap();
    }
}

#[test]
fn quantized_logits_error_monotone_in_bits() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let mut rng = asymkv::util::rng::SplitMix::new(11);
    // long enough to force folding (past the residual window)
    let doc = asymkv::workload::gen_document(&mut rng, 120);
    let tok = ByteTokenizer;
    let run = |policy: &QuantPolicy| -> Vec<f32> {
        let id = engine.create_seq(policy).unwrap();
        let l = engine
            .prefill(&[id], &[tok.encode(&doc)])
            .unwrap()
            .remove(0);
        engine.free_seq(id).unwrap();
        l
    };
    let float = run(&QuantPolicy::float32(n));
    let mut errs = Vec::new();
    for bits in [1u8, 2, 4] {
        let q = run(&QuantPolicy::kivi(n, bits));
        let mse: f64 = float
            .iter()
            .zip(&q)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / float.len() as f64;
        errs.push(mse);
    }
    assert!(
        errs[0] > errs[1] && errs[1] > errs[2],
        "logits error must shrink with bits: {errs:?}"
    );
}

#[test]
fn batched_prefill_matches_single() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let tok = ByteTokenizer;
    let mut rng = asymkv::util::rng::SplitMix::new(5);
    // different lengths exercise the padded-chunk path
    let p1 = tok.encode(&asymkv::workload::gen_document(&mut rng, 90));
    let p2 = tok.encode(&asymkv::workload::gen_document(&mut rng, 40));
    let policy = QuantPolicy::kivi(n, 2);

    let id1 = engine.create_seq(&policy).unwrap();
    let id2 = engine.create_seq(&policy).unwrap();
    let batched = engine
        .prefill(&[id1, id2], &[p1.clone(), p2.clone()])
        .unwrap();
    engine.free_seq(id1).unwrap();
    engine.free_seq(id2).unwrap();

    for (p, want) in [(p1, &batched[0]), (p2, &batched[1])] {
        let id = engine.create_seq(&policy).unwrap();
        let single = engine.prefill(&[id], &[p]).unwrap().remove(0);
        engine.free_seq(id).unwrap();
        let max_abs = single.iter().fold(0f32, |a, &b| a.max(b.abs()));
        for (a, b) in single.iter().zip(want.iter()) {
            assert!(
                (a - b).abs() < 3e-3 * max_abs.max(1.0),
                "batched vs single prefill diverged: {a} vs {b}"
            );
        }
    }
}

#[test]
fn memory_accounting_tracks_policy() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let m = engine.manifest();
    let n = m.n_layers;
    let (h, dh) = (m.n_heads, m.d_head);
    // grow past the residual window so the bit-dependent packed region
    // (not just the shared fp32 ring) is resident
    let fill = m.residual + m.group + 1;
    let mut caps = Vec::new();
    for policy in [
        QuantPolicy::kivi(n, 1),
        QuantPolicy::kivi(n, 2),
        QuantPolicy::float32(n),
    ] {
        let id = engine.create_seq(&policy).unwrap();
        // demand paging: a fresh sequence is charged (almost) nothing —
        // the policy's footprint materializes as the cache grows
        let fresh = engine.with_seq(id, |s| s.capacity_bytes()).unwrap();
        engine
            .with_seq(id, |s| {
                let row = vec![0.5f32; h * dh];
                for layer in &mut s.layers {
                    for _ in 0..fill {
                        layer.append_token(&row, &row);
                    }
                }
            })
            .unwrap();
        let grown = engine.with_seq(id, |s| s.capacity_bytes()).unwrap();
        assert!(fresh < grown, "pages must be charged on growth");
        caps.push(grown);
        engine.free_seq(id).unwrap();
    }
    assert!(caps[0] < caps[1] && caps[1] < caps[2], "{caps:?}");
    assert_eq!(engine.pool.stats().n_seqs, 0);
    assert!(engine.pool.stats().peak_bytes >= caps[2]);
}

#[test]
fn context_budget_enforced() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let m = engine.manifest();
    let policy = QuantPolicy::kivi(m.n_layers, 2);
    let id = engine.create_seq(&policy).unwrap();
    let too_long = vec![65i32; m.max_ctx + m.residual + 10];
    assert!(engine.prefill(&[id], &[too_long]).is_err());
    engine.free_seq(id).unwrap();
}

#[test]
fn engine_stats_progress() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let tok = ByteTokenizer;
    let mut rng = asymkv::util::rng::SplitMix::new(8);
    let doc = asymkv::workload::gen_document(&mut rng, 100); // > residual
    let id = engine.create_seq(&QuantPolicy::kivi(n, 2)).unwrap();
    engine
        .generate(&[id], &[tok.encode(&doc)], 3, &SamplingParams::greedy(), 0)
        .unwrap();
    engine.free_seq(id).unwrap();
    let st = engine.stats();
    assert!(st.prefill_chunks > 0);
    assert!(st.decode_steps > 0);
    assert!(st.folds > 0, "a 100-token prompt must fold past R=64");
    assert_eq!(st.tokens_generated, 3);
}

#[test]
fn runtime_rejects_malformed_calls() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let exe = engine.rt.executable("embed_b1_c1").unwrap();
    // wrong arg count
    assert!(exe.run(&[asymkv::runtime::lit_i32(&[1, 1], &[0]).unwrap()]).is_err());
    // wrong shape for tokens
    let m = engine.manifest();
    let embed = asymkv::runtime::lit_f32(
        &[m.vocab, m.d_model],
        &vec![0.0; m.vocab * m.d_model],
    )
    .unwrap();
    let bad_tokens = asymkv::runtime::lit_i32(&[1, 7], &[0; 7]).unwrap();
    assert!(exe.run(&[embed, bad_tokens]).is_err());
    // unknown artifact name
    assert!(engine.rt.executable("layer_b9_c9_k7_v7").is_err());
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let before = engine.rt.compiled_count();
    engine.rt.executable("head_b1_c1").unwrap();
    engine.rt.executable("head_b1_c1").unwrap();
    engine.rt.executable("head_b1_c1").unwrap();
    assert_eq!(engine.rt.compiled_count(), before + 1);
}

/// Interleaved decode across sequences created at different times — the
/// continuous-batching pattern at the engine level.
#[test]
fn interleaved_multi_sequence_decode() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let tok = ByteTokenizer;
    let policy = QuantPolicy::asymkv21(n, n / 2, 0);

    let a = engine.create_seq(&policy).unwrap();
    let prompt_a = tok.encode_str("## AAA:1111 ## AAA:");
    let len_a = prompt_a.len();
    engine.prefill(&[a], &[prompt_a]).unwrap();
    engine.decode(&[a], &[b'1' as i32]).unwrap();
    // b joins later; decode them together afterwards
    let b = engine.create_seq(&policy).unwrap();
    engine.prefill(&[b], &[tok.encode_str("the crow sings. ")]).unwrap();
    let logits = engine.decode(&[a, b], &[b'1' as i32, b't' as i32]).unwrap();
    assert_eq!(logits.len(), 2);
    assert!(logits.iter().all(|l| l.iter().all(|x| x.is_finite())));
    // positions advanced independently
    let pa = engine.with_seq(a, |s| s.pos).unwrap();
    let pb = engine.with_seq(b, |s| s.pos).unwrap();
    assert_eq!(pa, len_a + 2);
    assert_eq!(pb, 16 + 1);
    engine.free_seq(a).unwrap();
    engine.free_seq(b).unwrap();
}

#[test]
fn prefix_cache_reuse_matches_cold_prefill() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let tok = ByteTokenizer;
    let policy = QuantPolicy::kivi(n, 2);
    let pcache = asymkv::kvcache::PrefixCache::new(64 << 20);

    let base = tok.encode_str("## ABC:1234 XYZ:5678 ##");
    let full_a = tok.encode_str("## ABC:1234 XYZ:5678 ## ABC:");
    let full_b = tok.encode_str("## ABC:1234 XYZ:5678 ## XYZ:");

    // cold prefill of the shared base populates the cache
    let id0 = engine.create_seq(&policy).unwrap();
    engine.prefill_cached(&[id0], &[base.clone()], &pcache).unwrap();
    engine.free_seq(id0).unwrap();
    assert_eq!(pcache.stats().entries, 1);

    // warm path: full_a extends the cached base
    let id1 = engine.create_seq(&policy).unwrap();
    let warm = engine
        .prefill_cached(&[id1], &[full_a.clone()], &pcache)
        .unwrap()
        .remove(0);
    engine.free_seq(id1).unwrap();
    assert!(pcache.stats().hits >= 1);

    // cold reference without the cache
    let id2 = engine.create_seq(&policy).unwrap();
    let cold = engine.prefill(&[id2], &[full_a.clone()]).unwrap().remove(0);
    engine.free_seq(id2).unwrap();

    let max_abs = cold.iter().fold(0f32, |a, &b| a.max(b.abs()));
    for (w, c) in warm.iter().zip(&cold) {
        assert!((w - c).abs() < 3e-3 * max_abs.max(1.0),
                "warm {w} vs cold {c}");
    }

    // exact-hit fast path: same prompt again → logits from the snapshot
    let id3 = engine.create_seq(&policy).unwrap();
    let exact = engine
        .prefill_cached(&[id3], &[full_a.clone()], &pcache)
        .unwrap()
        .remove(0);
    engine.free_seq(id3).unwrap();
    assert_eq!(exact, warm);

    // a different continuation also reuses the base
    let hits_before = pcache.stats().hits;
    let id4 = engine.create_seq(&policy).unwrap();
    engine.prefill_cached(&[id4], &[full_b], &pcache).unwrap();
    engine.free_seq(id4).unwrap();
    assert!(pcache.stats().hits > hits_before);
}

/// Regression: the snapshot loop used `ids.iter().position(|&x| x == id)`
/// to find each sequence's logits — O(n²), and under duplicate ids it
/// attributed the FIRST duplicate's logits (and cache) to every duplicate.
/// Duplicate ids reach prefill_cached when several batch slots share one
/// sequence and all hit exactly (no batched prefill happens, so the
/// engine's duplicate-id batch panic never fires).
#[test]
fn prefix_cache_duplicate_ids_keep_per_prompt_state() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let tok = ByteTokenizer;
    let policy = QuantPolicy::kivi(n, 2);
    let pcache = asymkv::kvcache::PrefixCache::new(64 << 20);
    let pa = tok.encode_str("## ABC:1234 ## ABC:");
    let pb = tok.encode_str("## XYZ:9876 ## XYZ:");

    // seed exact-hit entries for both prompts
    for p in [&pa, &pb] {
        let id = engine.create_seq(&policy).unwrap();
        engine.prefill_cached(&[id], &[p.clone()], &pcache).unwrap();
        engine.free_seq(id).unwrap();
    }
    let ha = pcache.stats().hits;

    // the same sequence id rides in two slots with different prompts
    let id = engine.create_seq(&policy).unwrap();
    let out = engine
        .prefill_cached(&[id, id], &[pa.clone(), pb.clone()], &pcache)
        .unwrap();
    engine.free_seq(id).unwrap();
    assert!(pcache.stats().hits >= ha + 2, "both slots must hit");
    assert_ne!(out[0], out[1], "each slot must carry its own logits");

    // the stored entries must be untouched: replaying each prompt alone
    // returns exactly the logits the duplicate-id call reported for it
    for (p, want) in [(&pa, &out[0]), (&pb, &out[1])] {
        let id = engine.create_seq(&policy).unwrap();
        let got = engine
            .prefill_cached(&[id], &[p.clone()], &pcache)
            .unwrap()
            .remove(0);
        engine.free_seq(id).unwrap();
        assert_eq!(&got, want, "entry state poisoned by duplicate-id batch");
    }
}
