//! Proof that the steady-state decode GATHER PATH performs zero heap
//! allocation: a counting global allocator wraps the system allocator, and
//! the staged sync + arena mask fill of single-token steps — including
//! fold (tail-patch) steps — must not allocate at all. Appends and their
//! fold scratch run outside the measured region (they are the append path,
//! not the gather path).
//!
//! Lives in its own integration-test binary because `#[global_allocator]`
//! is process-wide.

use asymkv::engine::gather::{GatherGeo, StagedLayer, StepArena};
use asymkv::kvcache::{CacheGeometry, SeqCache};
use asymkv::quant::QuantPolicy;
use asymkv::util::bench::{alloc_events, CountingAlloc};
use asymkv::util::rng::SplitMix;

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_gather_path_allocates_nothing() {
    let cg = CacheGeometry {
        n_heads: 2, max_ctx: 128, d_head: 32, group: 32, residual: 64,
    };
    let gg = GatherGeo {
        b_art: 2, n_heads: 2, max_ctx: 128, d_head: 32, group: 32, residual: 64,
    };
    let n_layers = 2;
    let policy = QuantPolicy::kivi(n_layers, 1);
    let mut s0 = SeqCache::new(cg, &policy);
    let mut s1 = SeqCache::new(cg, &policy);
    let hd = 2 * 32;
    let mut rng = SplitMix::new(3);

    // warm past the first fold, then build the staging once
    for s in [&mut s0, &mut s1] {
        for layer in &mut s.layers {
            let ks = rng.normal_f32_vec(70 * hd);
            let vs = rng.normal_f32_vec(70 * hd);
            layer.append_tokens(70, &ks, &vs);
        }
    }
    let mut staged: Vec<StagedLayer> =
        (0..n_layers).map(|_| StagedLayer::new()).collect();
    let mut arena = StepArena::default();
    let ids = [1u64, 2];
    {
        let seqs = [&s0, &s1];
        arena.begin_step(&gg, 1, 8);
        for (li, st) in staged.iter_mut().enumerate() {
            st.sync(&gg, &ids, &seqs, li);
        }
    }

    // steady state: 40 single-token decode steps. The appended tokens (and
    // any fold scratch) run OUTSIDE the measured window; the measured
    // window is exactly what the engine's gather path does per step.
    let mut saw_patch = false;
    for step in 0..40 {
        let k = rng.normal_f32_vec(hd);
        for s in [&mut s0, &mut s1] {
            for layer in &mut s.layers {
                layer.append_token(&k, &k);
            }
        }
        let seqs = [&s0, &s1];

        let before = alloc_events();
        arena.begin_step(&gg, 1, 8);
        for (slot, seq) in seqs.iter().enumerate() {
            let lc = &seq.layers[0];
            for i in 0..lc.n_q {
                arena.mask_q[slot * 128 + i] = 0.0;
            }
            for i in 0..lc.n_res() {
                arena.mask_r[slot * 64 + i] = 0.0;
            }
        }
        let mut clean = true;
        for (li, st) in staged.iter_mut().enumerate() {
            let rep = st.sync(&gg, &ids, &seqs, li);
            clean &= rep.packed_clean;
            assert!(
                !rep.rebuilt && !rep.rescattered,
                "step {step}: steady state must never re-scatter"
            );
        }
        let allocated = alloc_events() - before;
        assert_eq!(allocated, 0, "step {step}: gather path allocated");
        if !clean {
            saw_patch = true;
        }
    }
    assert!(saw_patch, "40 steps past R must include fold/patch steps");
}
