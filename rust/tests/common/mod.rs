//! Shared helpers for integration tests: artifact discovery + engine setup.
#![allow(dead_code)] // each test binary uses a subset of these helpers

use std::path::PathBuf;
use std::sync::Arc;

use asymkv::engine::Engine;
use asymkv::runtime::Runtime;

/// Locate `artifacts/<model>` from the workspace root; None if not built.
pub fn artifact_dir(model: &str) -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join(model);
    p.join("manifest.json").exists().then_some(p)
}

/// Skip (returning None) with a notice when artifacts are missing.
pub fn engine_for(model: &str) -> Option<Arc<Engine>> {
    let dir = match artifact_dir(model) {
        Some(d) => d,
        None => {
            eprintln!("SKIP: artifacts/{model} not built (run `make artifacts`)");
            return None;
        }
    };
    let rt = Arc::new(Runtime::load(dir).expect("loading runtime"));
    Some(Arc::new(Engine::new(rt, 1 << 30).expect("building engine")))
}

/// Load golden.json for a model.
pub fn golden(model: &str) -> Option<asymkv::util::json::Value> {
    let dir = artifact_dir(model)?;
    let text = std::fs::read_to_string(dir.join("golden.json")).ok()?;
    Some(asymkv::util::json::parse(&text).expect("parsing golden.json"))
}
