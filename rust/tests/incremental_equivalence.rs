//! Property: the incremental decode fast path (persistent staged literals,
//! tail patches, pipelined gather) produces BYTE-IDENTICAL logits to the
//! `ASYMKV_NAIVE=1` baseline across random interleavings of prefill,
//! decode bursts (crossing fold boundaries), incremental prompt extension
//! (page growth, chunk boundaries) and preemption-requeue (free + replay),
//! for 1-bit KIVI and mixed layer-wise AsymKV policies.
//!
//! Two engines over the same artifacts run the identical op sequence; one
//! is pinned to the naive path via [`Engine::set_naive`]. Every logits row
//! is compared at the f32 bit level — not within a tolerance — because the
//! incremental path is a pure host-assembly optimization: the artifact
//! must receive the exact same bytes.

mod common;

use asymkv::quant::QuantPolicy;
use asymkv::util::prop::{check, Gen};

fn bits(l: &[f32]) -> Vec<u32> {
    l.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn incremental_logits_match_naive_prop() {
    let Some(fast) = common::engine_for("tiny") else { return };
    let Some(naive) = common::engine_for("tiny") else { return };
    naive.set_naive(true);
    assert!(!fast.is_naive(), "fast engine must run the incremental path");

    let n = fast.manifest().n_layers;
    let budget = fast.manifest().max_ctx + fast.manifest().residual - 2;
    let policies = [
        QuantPolicy::kivi(n, 1),              // the 1-bit flagship
        QuantPolicy::kivi(n, 2),
        QuantPolicy::asymkv21(n, n / 2, 0),   // mixed layer-wise bits
        QuantPolicy::float32(n),
    ];

    check("incremental_vs_naive", 4, |g: &mut Gen| {
        let policy = g.pick(&policies).clone();
        let tokens = |g: &mut Gen, len: usize| -> Vec<i32> {
            (0..len).map(|_| g.usize_in(32, 126) as i32).collect()
        };
        let mut fid = fast.create_seq(&policy).map_err(|e| e.to_string())?;
        let mut nid = naive.create_seq(&policy).map_err(|e| e.to_string())?;
        let mut history: Vec<i32> = tokens(g, g.usize_in(3, 80));

        let compare = |ctx: &str, lf: &[f32], ln: &[f32]| -> Result<(), String> {
            if bits(lf) != bits(ln) {
                return Err(format!(
                    "{ctx}: incremental logits diverge from naive ({policy})"
                ));
            }
            Ok(())
        };

        let lf = fast
            .prefill(&[fid], &[history.clone()])
            .map_err(|e| e.to_string())?;
        let ln = naive
            .prefill(&[nid], &[history.clone()])
            .map_err(|e| e.to_string())?;
        compare("prefill", &lf[0], &ln[0])?;

        for op in 0..g.usize_in(2, 5) {
            match g.usize_in(0, 3) {
                0 | 1 => {
                    // decode burst: long enough to cross fold boundaries
                    for step in 0..g.usize_in(1, 40) {
                        if history.len() + 1 > budget {
                            break;
                        }
                        let t = g.usize_in(32, 126) as i32;
                        let lf = fast.decode(&[fid], &[t]).map_err(|e| e.to_string())?;
                        let ln = naive.decode(&[nid], &[t]).map_err(|e| e.to_string())?;
                        compare(&format!("op {op} decode {step}"), &lf[0], &ln[0])?;
                        history.push(t);
                    }
                }
                2 => {
                    // extend the prompt mid-stream: chunked prefill on a
                    // non-empty cache (page growth + chunk boundaries)
                    let len = g.usize_in(1, 50);
                    if history.len() + len > budget {
                        continue;
                    }
                    let p = tokens(g, len);
                    let lf = fast
                        .prefill(&[fid], &[p.clone()])
                        .map_err(|e| e.to_string())?;
                    let ln = naive
                        .prefill(&[nid], &[p.clone()])
                        .map_err(|e| e.to_string())?;
                    compare(&format!("op {op} extend"), &lf[0], &ln[0])?;
                    history.extend(p);
                }
                _ => {
                    // preemption-requeue: free the sequence and replay its
                    // full history on a fresh one (what the scheduler does
                    // after a page-budget collision) — the fast engine's
                    // staged slots must invalidate, not serve stale bytes
                    fast.free_seq(fid).map_err(|e| e.to_string())?;
                    naive.free_seq(nid).map_err(|e| e.to_string())?;
                    fid = fast.create_seq(&policy).map_err(|e| e.to_string())?;
                    nid = naive.create_seq(&policy).map_err(|e| e.to_string())?;
                    let lf = fast
                        .prefill(&[fid], &[history.clone()])
                        .map_err(|e| e.to_string())?;
                    let ln = naive
                        .prefill(&[nid], &[history.clone()])
                        .map_err(|e| e.to_string())?;
                    compare(&format!("op {op} requeue"), &lf[0], &ln[0])?;
                }
            }
        }
        fast.free_seq(fid).map_err(|e| e.to_string())?;
        naive.free_seq(nid).map_err(|e| e.to_string())?;
        Ok(())
    });
}
