//! Property: the incremental decode fast path (persistent staged literals,
//! tail patches, pipelined gather) produces BYTE-IDENTICAL logits to the
//! `ASYMKV_NAIVE=1` baseline across random interleavings of prefill,
//! decode bursts (crossing fold boundaries), incremental prompt extension
//! (page growth, chunk boundaries) and preemption-requeue (free + replay),
//! for 1-bit KIVI and mixed layer-wise AsymKV policies.
//!
//! Two engines over the same artifacts run the identical op sequence; one
//! is pinned to the naive path via [`Engine::set_naive`]. Every logits row
//! is compared at the f32 bit level — not within a tolerance — because the
//! incremental path is a pure host-assembly optimization: the artifact
//! must receive the exact same bytes.

mod common;

use asymkv::quant::QuantPolicy;
use asymkv::util::prop::{check, Gen};

fn bits(l: &[f32]) -> Vec<u32> {
    l.iter().map(|x| x.to_bits()).collect()
}

fn run_interleaving_prop(label: &'static str) {
    let Some(fast) = common::engine_for("tiny") else { return };
    let Some(naive) = common::engine_for("tiny") else { return };
    naive.set_naive(true);
    assert!(!fast.is_naive(), "fast engine must run the incremental path");

    let n = fast.manifest().n_layers;
    let budget = fast.manifest().max_ctx + fast.manifest().residual - 2;
    let policies = [
        QuantPolicy::kivi(n, 1),              // the 1-bit flagship
        QuantPolicy::kivi(n, 2),
        QuantPolicy::asymkv21(n, n / 2, 0),   // mixed layer-wise bits
        QuantPolicy::float32(n),
    ];

    check(label, 4, |g: &mut Gen| {
        let policy = g.pick(&policies).clone();
        let tokens = |g: &mut Gen, len: usize| -> Vec<i32> {
            (0..len).map(|_| g.usize_in(32, 126) as i32).collect()
        };
        let mut fid = fast.create_seq(&policy).map_err(|e| e.to_string())?;
        let mut nid = naive.create_seq(&policy).map_err(|e| e.to_string())?;
        let mut history: Vec<i32> = tokens(g, g.usize_in(3, 80));

        let compare = |ctx: &str, lf: &[f32], ln: &[f32]| -> Result<(), String> {
            if bits(lf) != bits(ln) {
                return Err(format!(
                    "{ctx}: incremental logits diverge from naive ({policy})"
                ));
            }
            Ok(())
        };

        let lf = fast
            .prefill(&[fid], &[history.clone()])
            .map_err(|e| e.to_string())?;
        let ln = naive
            .prefill(&[nid], &[history.clone()])
            .map_err(|e| e.to_string())?;
        compare("prefill", &lf[0], &ln[0])?;

        for op in 0..g.usize_in(2, 5) {
            match g.usize_in(0, 3) {
                0 | 1 => {
                    // decode burst: long enough to cross fold boundaries
                    for step in 0..g.usize_in(1, 40) {
                        if history.len() + 1 > budget {
                            break;
                        }
                        let t = g.usize_in(32, 126) as i32;
                        let lf = fast.decode(&[fid], &[t]).map_err(|e| e.to_string())?;
                        let ln = naive.decode(&[nid], &[t]).map_err(|e| e.to_string())?;
                        compare(&format!("op {op} decode {step}"), &lf[0], &ln[0])?;
                        history.push(t);
                    }
                }
                2 => {
                    // extend the prompt mid-stream: chunked prefill on a
                    // non-empty cache (page growth + chunk boundaries)
                    let len = g.usize_in(1, 50);
                    if history.len() + len > budget {
                        continue;
                    }
                    let p = tokens(g, len);
                    let lf = fast
                        .prefill(&[fid], &[p.clone()])
                        .map_err(|e| e.to_string())?;
                    let ln = naive
                        .prefill(&[nid], &[p.clone()])
                        .map_err(|e| e.to_string())?;
                    compare(&format!("op {op} extend"), &lf[0], &ln[0])?;
                    history.extend(p);
                }
                _ => {
                    // preemption-requeue: free the sequence and replay its
                    // full history on a fresh one (what the scheduler does
                    // after a page-budget collision) — the fast engine's
                    // staged slots must invalidate, not serve stale bytes
                    fast.free_seq(fid).map_err(|e| e.to_string())?;
                    naive.free_seq(nid).map_err(|e| e.to_string())?;
                    fid = fast.create_seq(&policy).map_err(|e| e.to_string())?;
                    nid = naive.create_seq(&policy).map_err(|e| e.to_string())?;
                    let lf = fast
                        .prefill(&[fid], &[history.clone()])
                        .map_err(|e| e.to_string())?;
                    let ln = naive
                        .prefill(&[nid], &[history.clone()])
                        .map_err(|e| e.to_string())?;
                    compare(&format!("op {op} requeue"), &lf[0], &ln[0])?;
                }
            }
        }
        fast.free_seq(fid).map_err(|e| e.to_string())?;
        naive.free_seq(nid).map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn incremental_logits_match_naive_prop() {
    run_interleaving_prop("incremental_vs_naive");
}

/// The same interleaving property with each fast kernel tier pinned
/// process-wide: forcing `simd` or `fused` must leave every logits row
/// bit-identical, because the tiers are byte-identical on packed output
/// and the fused attention kernels are bit-identical to unfold-then-matmul
/// under the canonical summation orders. Safe to flip mid-process for the
/// same reason — concurrently running tests cannot observe a difference.
#[test]
fn incremental_logits_match_naive_with_simd_and_fused_kernels() {
    use asymkv::quant::kernels::{set_active_mode, KernelMode};
    set_active_mode(KernelMode::Simd);
    run_interleaving_prop("incremental_vs_naive_simd");
    set_active_mode(KernelMode::Fused);
    run_interleaving_prop("incremental_vs_naive_fused");
    set_active_mode(KernelMode::Auto); // back to the env-derived default
}

/// Property: sequences ATTACHED to a shared prefix node (copy-on-write
/// pages, process-wide staged literals, refcounted pool charge) produce
/// BYTE-IDENTICAL logits to plain unshared sequences that prefilled the
/// same history privately, across random interleavings of fork (attach),
/// decode bursts (first decode breaks CoW on the residual ring), suffix
/// prefill (divergence at group boundaries → page-level CoW) and mid-
/// flight release (shared pages must survive for the remaining forks).
/// Both sides run on ONE engine so shared and private sequences co-reside
/// in the same pool and staging, which is exactly the production shape.
#[test]
fn shared_prefix_cow_logits_match_unshared_prop() {
    let Some(eng) = common::engine_for("tiny") else { return };
    let n = eng.manifest().n_layers;
    let budget = eng.manifest().max_ctx + eng.manifest().residual - 2;
    let policies = [
        QuantPolicy::kivi(n, 1),
        QuantPolicy::kivi(n, 2),
        QuantPolicy::asymkv21(n, n / 2, 0),
        QuantPolicy::float32(n),
    ];

    check("shared_prefix_cow_vs_unshared", 4, |g: &mut Gen| {
        let policy = g.pick(&policies).clone();
        let tokens = |g: &mut Gen, len: usize| -> Vec<i32> {
            (0..len).map(|_| g.usize_in(32, 126) as i32).collect()
        };
        let compare = |ctx: &str, ls: &[f32], lp: &[f32]| -> Result<(), String> {
            if bits(ls) != bits(lp) {
                return Err(format!(
                    "{ctx}: shared-prefix logits diverge from unshared ({policy})"
                ));
            }
            Ok(())
        };

        // register the shared node (the prefix_register path): one prefill,
        // frozen + retained so the pages outlive every fork
        let prefix = tokens(g, g.usize_in(8, 64));
        let (base, base_logits) = eng
            .prefill_shared_base(&policy, &prefix)
            .map_err(|e| e.to_string())?;

        // (attached seq, plain twin, common history) triples
        let mut forks: Vec<(u64, u64, Vec<i32>)> = Vec::new();
        let result = (|| -> Result<(), String> {
            for op in 0..g.usize_in(4, 10) {
                match g.usize_in(0, 4) {
                    0 => {
                        // fork: attach the shared node (zero bytes copied)
                        // vs a private prefill of the same prefix — the
                        // node's stored logits must equal a fresh prefill's
                        if forks.len() >= 4 {
                            continue;
                        }
                        let s =
                            eng.create_seq_attached(&base).map_err(|e| e.to_string())?;
                        let p = eng.create_seq(&policy).map_err(|e| e.to_string())?;
                        let lp = eng
                            .prefill(&[p], &[prefix.clone()])
                            .map_err(|e| e.to_string())?;
                        compare(&format!("op {op} fork"), &base_logits, &lp[0])?;
                        forks.push((s, p, prefix.clone()));
                    }
                    1 | 2 => {
                        // decode burst: the fork's FIRST decode lands on the
                        // shared residual ring and must break copy-on-write,
                        // not write through into its siblings
                        if forks.is_empty() {
                            continue;
                        }
                        let f = g.usize_in(0, forks.len() - 1);
                        for step in 0..g.usize_in(1, 24) {
                            let (s, p, history) = &mut forks[f];
                            if history.len() + 1 > budget {
                                break;
                            }
                            let t = g.usize_in(32, 126) as i32;
                            let ls = eng.decode(&[*s], &[t]).map_err(|e| e.to_string())?;
                            let lp = eng.decode(&[*p], &[t]).map_err(|e| e.to_string())?;
                            compare(&format!("op {op} decode {step}"), &ls[0], &lp[0])?;
                            history.push(t);
                        }
                    }
                    3 => {
                        // suffix prefill: chunked divergence past the shared
                        // position (page growth off a CoW boundary)
                        if forks.is_empty() {
                            continue;
                        }
                        let f = g.usize_in(0, forks.len() - 1);
                        let len = g.usize_in(1, 40);
                        let (s, p, history) = &mut forks[f];
                        if history.len() + len > budget {
                            continue;
                        }
                        let suffix = tokens(g, len);
                        let ls = eng
                            .prefill(&[*s], &[suffix.clone()])
                            .map_err(|e| e.to_string())?;
                        let lp = eng
                            .prefill(&[*p], &[suffix.clone()])
                            .map_err(|e| e.to_string())?;
                        compare(&format!("op {op} suffix"), &ls[0], &lp[0])?;
                        history.extend(suffix);
                    }
                    _ => {
                        // release a fork mid-flight: the shared pages must
                        // survive (refcount) for every fork still attached
                        if forks.is_empty() {
                            continue;
                        }
                        let f = g.usize_in(0, forks.len() - 1);
                        let (s, p, _) = forks.swap_remove(f);
                        eng.free_seq(s).map_err(|e| e.to_string())?;
                        eng.free_seq(p).map_err(|e| e.to_string())?;
                    }
                }
            }
            Ok(())
        })();
        for (s, p, _) in forks {
            eng.free_seq(s).map_err(|e| e.to_string())?;
            eng.free_seq(p).map_err(|e| e.to_string())?;
        }
        // drop the registration's standalone reference: with every fork
        // gone this must free the shared bytes exactly once
        eng.pool.release_shared(base.id).map_err(|e| e.to_string())?;
        result
    });
}
