//! Full-stack test: TCP server → typed api → coordinator → engine →
//! artifacts. Covers the multiplexed v3 protocol (tagged concurrent
//! requests, cancellation, deadlines, universal streaming), the v2
//! protocol (typed errors, batch submit, sessions, policy management)
//! and the v1 compat shim.

mod common;

use std::sync::Arc;

use asymkv::api::{ApiRequest, GenerateSpec, SessionConfig};
use asymkv::coordinator::{Coordinator, CoordinatorConfig, Request};
use asymkv::model::ByteTokenizer;
use asymkv::quant::QuantPolicy;
use asymkv::server::{Client, MuxClient, Server};
use asymkv::util::json::Value;

/// Boot a server over `coord`; returns (server, addr). The accept loop
/// thread exits on `server.request_stop()`.
fn boot(coord: Arc<Coordinator>) -> (Arc<Server>, String) {
    boot_with(coord, |_| {})
}

/// Boot with a hook to adjust the server (inflight cap, session config is
/// set via `Server::bind_with` callers) before the accept loop starts.
fn boot_with(
    coord: Arc<Coordinator>,
    tweak: impl FnOnce(&mut Server),
) -> (Arc<Server>, String) {
    let mut server = Server::bind(coord, "127.0.0.1:0").unwrap();
    tweak(&mut server);
    let server = Arc::new(server);
    let addr = server.local_addr();
    {
        let srv = server.clone();
        std::thread::spawn(move || srv.serve());
    }
    (server, addr)
}

#[test]
fn coordinator_roundtrip_and_batching() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_active: 8,
            max_batch: 4,
            batch_window: std::time::Duration::from_millis(5),
            prefix_cache_bytes: 0,
            downshift: true,
        },
    );
    let tok = ByteTokenizer;
    // several concurrent requests with mixed policies — the scheduler must
    // group policy-homogeneous batches and still answer everyone
    let mut handles = Vec::new();
    for i in 0..6u64 {
        let policy = if i % 2 == 0 {
            QuantPolicy::kivi(n, 2)
        } else {
            QuantPolicy::float32(n)
        };
        let mut rng = asymkv::util::rng::SplitMix::new(i);
        let ep = asymkv::workload::tasks::recall_episode(&mut rng, 3);
        handles.push(coord.submit(Request::greedy(
            i,
            tok.encode(&ep.prompt),
            5,
            policy,
        )));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait();
        assert!(resp.error.is_none(), "req {i}: {:?}", resp.error);
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.timing.total_s > 0.0);
    }
    let m = coord.metrics();
    assert_eq!(m.requests_completed, 6);
    assert_eq!(m.requests_failed, 0);
    assert!(m.tokens_generated >= 30);
    coord.shutdown();
}

#[test]
fn multibyte_stop_sequence_truncates_generation() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let tok = ByteTokenizer;
    let prompt = tok.encode_str("the ox runs. ");
    // reference run: greedy generation is deterministic
    let r1 = coord.submit_wait(Request::greedy(
        1,
        prompt.clone(),
        24,
        QuantPolicy::float32(n),
    ));
    assert!(r1.error.is_none(), "{:?}", r1.error);
    assert_eq!(r1.tokens.len(), 24);
    // a two-token window of the reference output is guaranteed to recur —
    // the multi-byte stop sequence must cut the second run short exactly
    // when that tail appears
    let stop: Vec<i32> = r1.tokens[3..5].to_vec();
    let mut req = Request::greedy(2, prompt, 24, QuantPolicy::float32(n));
    req.stop_seq = stop.clone();
    let r2 = coord.submit_wait(req);
    assert!(r2.error.is_none(), "{:?}", r2.error);
    assert!(r2.tokens.len() < 24, "stop sequence must cut generation short");
    assert!(
        r2.tokens.ends_with(&stop),
        "{:?} must end with {:?}",
        r2.tokens,
        stop
    );
    assert_eq!(
        r2.tokens[..],
        r1.tokens[..r2.tokens.len()],
        "stopped run must be a prefix of the reference run"
    );
    coord.shutdown();
}

#[test]
fn tcp_server_end_to_end_v1_compat() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);

    let mut client = Client::connect(&addr).unwrap();
    // ping — exact legacy line, no "v" field
    let pong = client
        .call(&Value::obj(vec![("op", Value::str_of("ping"))]))
        .unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    assert!(pong.get("v").as_f64().is_none(), "v1 replies carry no version");
    // generate
    let reply = client
        .call(&Value::obj(vec![
            ("op", Value::str_of("generate")),
            ("prompt", Value::str_of("## ABC:1234 ## ABC:")),
            ("n_gen", Value::num(4.0)),
            ("policy", Value::str_of("kivi-2")),
        ]))
        .unwrap();
    assert!(reply.get("error").as_str().is_none(), "{reply}");
    assert_eq!(reply.get("tokens").as_arr().unwrap().len(), 4);
    assert!(reply.get("total_s").as_f64().unwrap() > 0.0);
    // stats + pool introspection
    let stats = client
        .call(&Value::obj(vec![("op", Value::str_of("stats"))]))
        .unwrap();
    assert!(stats.get("requests_completed").as_i64().unwrap() >= 1);
    let pool = client
        .call(&Value::obj(vec![("op", Value::str_of("pool"))]))
        .unwrap();
    assert!(pool.get("peak_bytes").as_f64().unwrap() > 0.0);
    // malformed line → v1 string error, connection stays usable
    let err = client.call(&Value::str_of("not an object")).unwrap();
    assert!(err.get("error").as_str().is_some());
    let pong2 = client
        .call(&Value::obj(vec![("op", Value::str_of("ping"))]))
        .unwrap();
    assert_eq!(pong2.get("ok").as_bool(), Some(true));

    server.request_stop();
}

#[test]
fn v2_typed_errors_and_policy_management() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mut client = Client::connect(&addr).unwrap();

    let v2 = |fields: Vec<(&str, Value)>| {
        let mut all = vec![("v", Value::num(2.0))];
        all.extend(fields);
        Value::obj(all)
    };
    let code = |r: &Value| r.get("error").get("code").as_str().map(str::to_string);

    // distinct error codes, not silent defaults
    let r = client.call(&v2(vec![("op", Value::str_of("frobnicate"))])).unwrap();
    assert_eq!(code(&r).as_deref(), Some("unknown_op"), "{r}");
    let r = client.call(&v2(vec![("op", Value::str_of("generate"))])).unwrap();
    assert_eq!(code(&r).as_deref(), Some("missing_field"), "{r}");
    let r = client
        .call(&v2(vec![
            ("op", Value::str_of("generate")),
            ("prompt", Value::str_of("x")),
            ("policy", Value::str_of("wat")),
        ]))
        .unwrap();
    assert_eq!(code(&r).as_deref(), Some("bad_policy"), "{r}");
    // parses but was never lowered into the artifact grid
    let r = client
        .call(&v2(vec![
            ("op", Value::str_of("generate")),
            ("prompt", Value::str_of("x")),
            ("policy", Value::str_of("kivi-8")),
        ]))
        .unwrap();
    assert_eq!(code(&r).as_deref(), Some("unsupported_policy"), "{r}");
    // empty stop is a typed error, not a silent no-op
    let r = client
        .call(&v2(vec![
            ("op", Value::str_of("generate")),
            ("prompt", Value::str_of("x")),
            ("stop", Value::str_of("")),
        ]))
        .unwrap();
    assert_eq!(code(&r).as_deref(), Some("empty_stop"), "{r}");

    // policy management: listing + server-side validation probes
    let r = client.send(&ApiRequest::Policies { policy: None }).unwrap();
    assert_eq!(r.get("v").as_i64(), Some(2));
    assert!(!r.get("grid").as_arr().unwrap().is_empty());
    assert!(!r.get("policies").as_arr().unwrap().is_empty(), "{r}");
    let r = client
        .send(&ApiRequest::Policies { policy: Some("kivi-2".into()) })
        .unwrap();
    let ps = r.get("policies").as_arr().unwrap();
    assert_eq!(ps.len(), 1, "{r}");
    assert_eq!(ps[0].get("name").as_str(), Some("KIVI-2bit"));
    assert!(ps[0].get("bytes_per_token").as_f64().unwrap() > 0.0);
    let r = client
        .send(&ApiRequest::Policies { policy: Some("kivi-8".into()) })
        .unwrap();
    assert_eq!(code(&r).as_deref(), Some("unsupported_policy"), "{r}");

    server.request_stop();
}

#[test]
fn batch_generate_returns_per_item_results() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mut client = Client::connect(&addr).unwrap();

    let items = vec![
        GenerateSpec {
            prompt: "## ABC:1234 ## ABC:".into(),
            n_gen: 4,
            policy: Some(QuantPolicy::kivi(n, 2)),
            ..Default::default()
        },
        GenerateSpec {
            prompt: "the ox runs. ".into(),
            n_gen: 3,
            policy: Some(QuantPolicy::kivi(n, 2)),
            ..Default::default()
        },
        // per-item failure: unsupported policy must not sink the batch
        GenerateSpec {
            prompt: "x".into(),
            n_gen: 2,
            policy: Some(QuantPolicy::kivi(n, 8)),
            ..Default::default()
        },
    ];
    let r = client.send(&ApiRequest::BatchGenerate { items }).unwrap();
    assert_eq!(r.get("n").as_i64(), Some(3), "{r}");
    let results = r.get("results").as_arr().unwrap();
    assert_eq!(results[0].get("tokens").as_arr().unwrap().len(), 4);
    assert_eq!(results[1].get("tokens").as_arr().unwrap().len(), 3);
    assert_eq!(
        results[2].get("error").get("code").as_str(),
        Some("unsupported_policy"),
        "{r}"
    );
    let stats = client.send(&ApiRequest::Stats).unwrap();
    assert_eq!(stats.get("batch_requests").as_i64(), Some(1));
    assert_eq!(stats.get("batch_items").as_i64(), Some(3));

    server.request_stop();
}

#[test]
fn session_reuses_kv_across_turns_without_reprefill() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let chunk = engine.manifest().chunk;
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mut client = Client::connect(&addr).unwrap();

    let opened = client
        .send(&ApiRequest::SessionOpen {
            policy: Some(QuantPolicy::float32(n)),
            prefix_id: None,
        })
        .unwrap();
    assert_eq!(opened.get("v").as_i64(), Some(2), "{opened}");
    let session = opened.get("session").as_i64().unwrap() as u64;
    assert_eq!(opened.get("policy").as_str(), Some("float"));
    assert_eq!(server.coord.engine().pool.stats().pinned_seqs, 1);

    // turn 1: a prompt spanning multiple prefill chunks
    let mut p1 = String::new();
    while p1.len() <= chunk + 4 {
        p1.push_str("the ox runs. the fox hides. ");
    }
    let stats0 = server.coord.engine().stats();
    let t1 = client
        .send(&ApiRequest::SessionAppend {
            session,
            spec: GenerateSpec { prompt: p1.clone(), n_gen: 3, ..Default::default() },
        })
        .unwrap();
    assert_eq!(t1.get("error"), &Value::Null, "{t1}");
    assert_eq!(t1.get("turn").as_i64(), Some(1), "{t1}");
    assert_eq!(t1.get("tokens").as_arr().unwrap().len(), 3);
    let stats1 = server.coord.engine().stats();
    let turn1_chunks = stats1.prefill_chunks - stats0.prefill_chunks;
    assert!(turn1_chunks >= 2, "turn-1 prompt must span chunks ({turn1_chunks})");
    assert_eq!(t1.get("pos").as_usize(), Some(p1.len() + 3));

    // turn 2: a short delta. KV reuse means ONLY the delta is prefilled —
    // a re-prefill of the turn-1 history would cost >= turn1_chunks again.
    let p2 = "and then";
    assert!(p2.len() < chunk);
    let t2 = client
        .send(&ApiRequest::SessionAppend {
            session,
            spec: GenerateSpec { prompt: p2.into(), n_gen: 3, ..Default::default() },
        })
        .unwrap();
    assert_eq!(t2.get("turn").as_i64(), Some(2), "{t2}");
    let stats2 = server.coord.engine().stats();
    let turn2_chunks = stats2.prefill_chunks - stats1.prefill_chunks;
    assert_eq!(
        turn2_chunks, 1,
        "second turn must prefill only the delta chunk, not the history"
    );
    assert_eq!(t2.get("pos").as_usize(), Some(p1.len() + 3 + p2.len() + 3));

    // concurrent append to the same session is a typed error
    // (exercised at the manager level by a second client mid-flight being
    // impossible to time reliably here; unknown_session covers the path)

    // close releases the pinned sequence
    let closed = client.send(&ApiRequest::SessionClose { session }).unwrap();
    assert_eq!(closed.get("turns").as_i64(), Some(2), "{closed}");
    assert_eq!(closed.get("closed").as_bool(), Some(true));
    let ps = server.coord.engine().pool.stats();
    assert_eq!((ps.n_seqs, ps.pinned_seqs), (0, 0), "close must free the cache");

    // the session is gone: appends and closes are typed errors
    let gone = client
        .send(&ApiRequest::SessionAppend {
            session,
            spec: GenerateSpec { prompt: "x".into(), n_gen: 1, ..Default::default() },
        })
        .unwrap();
    assert_eq!(
        gone.get("error").get("code").as_str(),
        Some("unknown_session"),
        "{gone}"
    );
    let gone = client.send(&ApiRequest::SessionClose { session }).unwrap();
    assert_eq!(gone.get("error").get("code").as_str(), Some("unknown_session"));

    // session metrics recorded
    let stats = client.send(&ApiRequest::Stats).unwrap();
    assert_eq!(stats.get("sessions_opened").as_i64(), Some(1));
    assert_eq!(stats.get("sessions_closed").as_i64(), Some(1));

    server.request_stop();
}

#[test]
fn unsupported_policy_rejected_cleanly() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    // 8-bit variants were never lowered — must fail the request, not wedge
    let resp = coord.submit_wait(Request::greedy(
        1,
        vec![65, 66],
        2,
        QuantPolicy::kivi(n, 8),
    ));
    assert!(resp.error.is_some());
    let m = coord.metrics();
    assert_eq!(m.requests_failed, 1);
    coord.shutdown();
}

#[test]
fn backpressure_under_tiny_pool_budget() {
    // pool sized for ~2 of this workload's sequences: 8 concurrent
    // requests must still all complete via queueing + requeue on
    // BudgetExceeded (the pool is demand-paged, so size the budget from
    // the projected per-request footprint, not a full-context reservation)
    let Some(dir) = common::artifact_dir("tiny") else { return };
    let rt = Arc::new(asymkv::runtime::Runtime::load(dir).unwrap());
    let probe = asymkv::engine::Engine::new(rt.clone(), usize::MAX).unwrap();
    let n = probe.manifest().n_layers;
    let one = {
        let tok = ByteTokenizer;
        let policy = QuantPolicy::float32(n);
        (0..8u64)
            .map(|i| {
                let mut rng = asymkv::util::rng::SplitMix::new(i);
                let ep = asymkv::workload::tasks::recall_episode(&mut rng, 2);
                probe
                    .pool
                    .estimate_bytes(&policy, tok.encode(&ep.prompt).len() + 3)
            })
            .max()
            .unwrap()
    };
    drop(probe);
    let engine =
        Arc::new(asymkv::engine::Engine::new(rt, one * 2 + one / 2).unwrap());
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_active: 8,
            max_batch: 4,
            batch_window: std::time::Duration::from_millis(1),
            prefix_cache_bytes: 0,
            downshift: true,
        },
    );
    let tok = ByteTokenizer;
    let handles: Vec<_> = (0..8u64)
        .map(|i| {
            let mut rng = asymkv::util::rng::SplitMix::new(i);
            let ep = asymkv::workload::tasks::recall_episode(&mut rng, 2);
            coord.submit(Request::greedy(
                i,
                tok.encode(&ep.prompt),
                3,
                QuantPolicy::float32(n),
            ))
        })
        .collect();
    for h in handles {
        let r = h.wait();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 3);
    }
    assert_eq!(coord.metrics().requests_completed, 8);
    // all caches released
    assert_eq!(coord.engine().pool.stats().n_seqs, 0);
    coord.shutdown();
}

#[test]
fn priority_ordering_respected() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    // single-slot coordinator: strictly serial execution exposes ordering.
    // max_batch stays above 1 so the batching window still applies — at
    // max_batch = 1 a single queued request is already a full batch and
    // the scheduler (correctly) skips the linger.
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_active: 1,
            max_batch: 2,
            batch_window: std::time::Duration::from_millis(30),
            prefix_cache_bytes: 0,
            downshift: true,
        },
    );
    let tok = ByteTokenizer;
    let order = Arc::new(std::sync::Mutex::new(Vec::new()));
    let mut handles = vec![];
    // submit low-priority first, then high — within the batch window both
    // are queued, and the high-priority one must run first
    for (id, prio) in [(1u64, 0i32), (2, 5), (3, 5), (4, 0)] {
        let mut req = Request::greedy(
            id,
            tok.encode_str("the ox runs. the"),
            2,
            QuantPolicy::float32(n),
        );
        req.priority = prio;
        let h = coord.submit(req);
        let order = order.clone();
        handles.push(std::thread::spawn(move || {
            let r = h.wait();
            assert!(r.error.is_none());
            order.lock().unwrap().push(id);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let got = order.lock().unwrap().clone();
    // high-priority ids (2, 3) complete before low-priority (1, 4)
    let pos = |id: u64| got.iter().position(|&x| x == id).unwrap();
    assert!(pos(2) < pos(1) && pos(2) < pos(4), "order {got:?}");
    assert!(pos(3) < pos(1) && pos(3) < pos(4), "order {got:?}");
    coord.shutdown();
}

#[test]
fn shutdown_drains_in_flight_work() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let tok = ByteTokenizer;
    let h = coord.submit(Request::greedy(
        1,
        tok.encode_str("## AAB:1290 ## AAB:"),
        4,
        QuantPolicy::kivi(n, 2),
    ));
    coord.shutdown(); // must not drop the in-flight request
    let r = h.wait();
    assert!(r.error.is_none());
    assert_eq!(r.tokens.len(), 4);
}

#[test]
fn oversized_request_fails_fast_not_livelock() {
    // a request whose cache alone exceeds the TOTAL budget must be failed,
    // not requeued forever
    let Some(dir) = common::artifact_dir("tiny") else { return };
    let rt = Arc::new(asymkv::runtime::Runtime::load(dir).unwrap());
    let engine = Arc::new(asymkv::engine::Engine::new(rt, 1024).unwrap()); // 1 KiB
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let resp = coord.submit_wait(Request::greedy(
        1,
        vec![65, 66, 67],
        2,
        QuantPolicy::float32(n),
    ));
    assert!(resp.error.is_some(), "must fail, not hang");
    assert!(resp.error.unwrap().contains("admission failed"));
    coord.shutdown();
}

#[test]
fn streaming_generate_emits_token_lines() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    // raw client: one request line, then read until "done":true
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(
        w,
        r#"{{"op":"generate","prompt":"the ox runs. ","n_gen":5,"stream":true,"policy":"kivi-2"}}"#
    )
    .unwrap();
    let mut pieces = Vec::new();
    let mut final_tokens = None;
    for _ in 0..64 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = asymkv::util::json::parse(line.trim()).unwrap();
        if v.get("done").as_bool() == Some(true) {
            assert!(v.get("error").as_str().is_none(), "{v}");
            final_tokens = Some(v.get("tokens").as_arr().unwrap().len());
            break;
        }
        pieces.push(v.get("token").as_i64().unwrap());
    }
    assert_eq!(final_tokens, Some(5));
    assert_eq!(pieces.len(), 5, "one streamed line per token");
    server.request_stop();
}

#[test]
fn prefix_cache_accelerates_shared_prompts() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            prefix_cache_bytes: 64 << 20,
            ..Default::default()
        },
    );
    let tok = ByteTokenizer;
    let prompt = "## AAB:1290 ZZT:4456 ## ZZT:";
    // same prompt three times: 2nd/3rd hit the snapshot
    let mut outs = Vec::new();
    for i in 0..3u64 {
        let r = coord.submit_wait(Request::greedy(
            i,
            tok.encode_str(prompt),
            4,
            QuantPolicy::kivi(n, 2),
        ));
        assert!(r.error.is_none());
        outs.push(r.tokens);
    }
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[1], outs[2]);
    let ps = coord.prefix_stats().unwrap();
    assert!(ps.hits >= 2, "prefix stats {ps:?}");
    assert!(ps.entries >= 1);
    coord.shutdown();
}

#[test]
fn preemption_requeues_and_preserves_output() {
    // Over-subscribed pool: optimistic paged admission lets several long
    // generations start, their page growth collides mid-decode, and the
    // scheduler must preempt + requeue (never panic, never fail) with
    // byte-identical greedy output to an uncontended run. `downshift` is
    // off here to pin the strict evict-and-replay path — the in-place
    // downshift alternative is covered by
    // `downshift_frees_pages_before_preemption` below.
    let Some(dir) = common::artifact_dir("tiny") else { return };
    let rt = Arc::new(asymkv::runtime::Runtime::load(dir).unwrap());
    let tok = ByteTokenizer;
    let n_gen = 24usize;
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| tok.encode_str(&format!("the ox {i} runs over the lazy dog. the")))
        .collect();

    let run = |budget: usize| -> (Vec<Vec<i32>>, u64) {
        let engine =
            Arc::new(asymkv::engine::Engine::new(rt.clone(), budget).unwrap());
        let n = engine.manifest().n_layers;
        let coord = Coordinator::start(
            engine,
            CoordinatorConfig {
                max_active: 4,
                max_batch: 4,
                batch_window: std::time::Duration::from_millis(1),
                prefix_cache_bytes: 0,
                downshift: false,
            },
        );
        let handles: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                coord.submit(Request::greedy(
                    i as u64,
                    p.clone(),
                    n_gen,
                    QuantPolicy::float32(n),
                ))
            })
            .collect();
        let mut outs = Vec::new();
        for h in handles {
            let r = h.wait();
            assert!(r.error.is_none(), "request failed: {:?}", r.error);
            assert_eq!(r.tokens.len(), n_gen);
            outs.push(r.tokens);
        }
        let m = coord.metrics();
        assert_eq!(m.downshifts, 0, "downshift disabled by config");
        assert_eq!(coord.engine().pool.stats().n_seqs, 0, "caches released");
        coord.shutdown();
        (outs, m.preemptions)
    };

    // reference: unconstrained pool, no preemption possible
    let (reference, p0) = run(usize::MAX);
    assert_eq!(p0, 0);
    // constrained: room for ~1.5 fully grown request footprints
    let one = {
        let probe =
            asymkv::engine::Engine::new(rt.clone(), usize::MAX).unwrap();
        let n = probe.manifest().n_layers;
        let longest = prompts.iter().map(|p| p.len()).max().unwrap();
        probe
            .pool
            .estimate_bytes(&QuantPolicy::float32(n), longest + n_gen)
    };
    let (contended, preemptions) = run(one + one / 2);
    assert_eq!(
        contended, reference,
        "preempted-then-retried output must equal the uninterrupted output"
    );
    // the budget really over-subscribed: growth collided at least once
    assert!(
        preemptions > 0,
        "expected mid-decode preemptions under a {} byte budget",
        one + one / 2
    );
}

#[test]
fn downshift_frees_pages_before_preemption() {
    // Over-subscribed pool with the pressure-adaptive path ON: when page
    // growth collides mid-decode, the scheduler re-quantizes a victim's
    // cold (already-folded) groups in place one grid rung down instead of
    // evicting it. Victims keep decoding at lower precision, the repack
    // returns pages to the pool (`downshift_bytes_freed`), and preemption
    // remains only as the fallback once everyone sits at the grid floor.
    let Some(dir) = common::artifact_dir("tiny") else { return };
    let rt = Arc::new(asymkv::runtime::Runtime::load(dir).unwrap());
    let tok = ByteTokenizer;
    // Prompts longer than half the residual window pre-page the whole
    // fp32 ring at prefill; the long generated tail then folds groups
    // into the quantized region, whose pages the budget runs out of —
    // exactly the bytes a downshift can shrink.
    let n_gen = 140usize;
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| {
            tok.encode_str(&format!(
                "the ox {i} runs over the lazy dog and the dog naps. the"
            ))
        })
        .collect();
    let probe = asymkv::engine::Engine::new(rt.clone(), usize::MAX).unwrap();
    let n = probe.manifest().n_layers;
    // every layer at (2, 2): one grid rung above the (1, 1) floor
    let policy = QuantPolicy::kivi(n, 2);
    let longest = prompts.iter().map(|p| p.len()).max().unwrap();
    let at_prefill = probe.pool.estimate_bytes(&policy, longest);
    let full = probe.pool.estimate_bytes(&policy, longest + n_gen);
    drop(probe);
    // two prefill footprints fit, but only HALF the pair's subsequent
    // quantized-region growth does: the collision is guaranteed to land
    // mid-decode, after both sequences hold cold folded groups
    let budget = 2 * at_prefill + (full - at_prefill);
    let engine = Arc::new(asymkv::engine::Engine::new(rt.clone(), budget).unwrap());
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_active: 4,
            max_batch: 4,
            batch_window: std::time::Duration::from_millis(1),
            prefix_cache_bytes: 0,
            downshift: true,
        },
    );
    let handles: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            coord.submit(Request::greedy(i as u64, p.clone(), n_gen, policy.clone()))
        })
        .collect();
    for h in handles {
        let r = h.wait();
        assert!(r.error.is_none(), "request failed: {:?}", r.error);
        assert_eq!(r.tokens.len(), n_gen, "downshifted victims still finish");
    }
    let m = coord.metrics();
    assert!(
        m.downshifts >= 1,
        "expected an in-place downshift under a {budget} byte budget \
         (preemptions: {})",
        m.preemptions
    );
    assert!(m.downshift_bytes_freed > 0, "a downshift must return pages");
    let ps = coord.engine().pool.stats();
    assert_eq!(ps.n_seqs, 0, "caches released");
    assert_eq!(ps.in_use_bytes, 0);
    assert_eq!(
        ps.page_alloc_bytes, ps.page_free_bytes,
        "page ledger reconciles: every byte granted by a downshifted run \
         was returned"
    );
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// v3: multiplexed tagged requests, cancellation, deadlines, streaming
// ---------------------------------------------------------------------------

#[test]
fn v3_eight_concurrent_tagged_requests_one_socket() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mux = MuxClient::connect(&addr).unwrap();

    // 8 generates with DISTINCT n_gen submitted before reading a single
    // reply: each reply must come back on its own tag with its own token
    // count — a cross-tag mixup cannot produce 8 distinct correct counts
    let pendings: Vec<_> = (0..8usize)
        .map(|i| {
            mux.submit(&ApiRequest::Generate(GenerateSpec {
                prompt: "the ox runs. the".into(),
                n_gen: 16 + i,
                ..Default::default()
            }))
            .unwrap()
        })
        .collect();
    // all 8 are registered long before the first finishes 16+ decode
    // steps — the peak gauge must have seen the full fan-in
    for (i, p) in pendings.iter().enumerate() {
        let v = p.wait_done().unwrap();
        assert_eq!(v.get("v").as_i64(), Some(3), "{v}");
        assert_eq!(v.get("tag").as_i64(), Some(p.tag as i64), "{v}");
        assert_eq!(v.get("error"), &Value::Null, "{v}");
        assert_eq!(
            v.get("tokens").as_arr().unwrap().len(),
            16 + i,
            "tag {} got the wrong generation",
            p.tag
        );
    }
    let stats = mux.submit(&ApiRequest::Stats).unwrap().wait_done().unwrap();
    assert!(
        stats.get("inflight_peak").as_i64().unwrap() >= 8,
        "one socket must sustain 8 concurrent in-flight requests: {stats}"
    );
    assert_eq!(stats.get("inflight").as_i64(), Some(0), "{stats}");
    server.request_stop();
}

#[test]
fn v3_instant_ops_overtake_inflight_generation() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mux = MuxClient::connect(&addr).unwrap();

    // a long generation is submitted FIRST...
    let slow = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "the ox runs. the".into(),
            n_gen: 48,
            ..Default::default()
        }))
        .unwrap();
    // ...yet stats (submitted second) replies first, and observes the
    // generation still in flight — out-of-order, tag-correlated replies
    let stats = mux.submit(&ApiRequest::Stats).unwrap().wait_done().unwrap();
    assert!(
        stats.get("inflight").as_i64().unwrap() >= 1,
        "the generation must still be running when stats answers: {stats}"
    );
    let done = slow.wait_done().unwrap();
    assert_eq!(done.get("tokens").as_arr().unwrap().len(), 48, "{done}");
    server.request_stop();
}

#[test]
fn v3_cancel_mid_stream_frees_pool_pages() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let baseline = engine.pool.stats().in_use_bytes;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mux = MuxClient::connect(&addr).unwrap();

    // a long streaming generation (100 decode steps at tiny geometry)
    let gen = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "the ox runs. ".into(),
            n_gen: 100,
            stream: true,
            ..Default::default()
        }))
        .unwrap();
    // read a few streamed tokens to prove it is mid-decode...
    for _ in 0..3 {
        let f = gen.recv().unwrap();
        assert!(f.get("token").as_i64().is_some(), "{f}");
        assert_eq!(f.get("tag").as_i64(), Some(gen.tag as i64), "{f}");
    }
    // ...then cancel it
    let cr = mux.cancel(gen.tag).unwrap().wait_done().unwrap();
    assert_eq!(cr.get("cancelled").as_bool(), Some(true), "{cr}");
    assert_eq!(cr.get("target").as_i64(), Some(gen.tag as i64), "{cr}");
    // the request completes with the typed cancelled error (after at most
    // a handful of frames that raced the cancel)
    let done = gen.wait_done().unwrap();
    assert_eq!(
        done.get("error").get("code").as_str(),
        Some("cancelled"),
        "{done}"
    );
    // the sequence's pool pages were freed BEFORE the final frame was
    // fulfilled — resident bytes are already back at baseline
    let ps = server.coord.engine().pool.stats();
    assert_eq!(ps.in_use_bytes, baseline, "cancel must free pages: {ps:?}");
    assert_eq!(ps.n_seqs, 0);
    // the abort is counted as a cancel, not a failure
    let stats = mux.submit(&ApiRequest::Stats).unwrap().wait_done().unwrap();
    assert_eq!(stats.get("cancelled").as_i64(), Some(1), "{stats}");
    assert_eq!(stats.get("requests_failed").as_i64(), Some(0), "{stats}");
    // cancelling a finished (or unknown) tag reports false
    let cr = mux.cancel(gen.tag).unwrap().wait_done().unwrap();
    assert_eq!(cr.get("cancelled").as_bool(), Some(false), "{cr}");
    server.request_stop();
}

#[test]
fn v3_deadline_expires_queued_request() {
    let Some(engine) = common::engine_for("tiny") else { return };
    // single-slot coordinator: the second request stays QUEUED while the
    // first runs its 150 decode steps
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_active: 1,
            max_batch: 2,
            batch_window: std::time::Duration::from_millis(1),
            prefix_cache_bytes: 0,
            downshift: true,
        },
    );
    let (server, addr) = boot(coord);
    let mux = MuxClient::connect(&addr).unwrap();
    let slow = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "the ox runs. ".into(),
            n_gen: 150,
            stream: true,
            ..Default::default()
        }))
        .unwrap();
    // wait for the first streamed token: the slow request now owns the
    // single active slot with ~149 decode steps to go, so the doomed one
    // below is deterministically QUEUED when its 5 ms deadline passes
    let first = slow.recv().unwrap();
    assert!(first.get("token").as_i64().is_some(), "{first}");
    let doomed = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "the fox hides. ".into(),
            n_gen: 4,
            deadline_ms: Some(5),
            ..Default::default()
        }))
        .unwrap();
    let v = doomed.wait_done().unwrap();
    assert_eq!(
        v.get("error").get("code").as_str(),
        Some("deadline_exceeded"),
        "{v}"
    );
    let fin = slow.wait_done().unwrap();
    assert_eq!(fin.get("tokens").as_arr().unwrap().len(), 150, "{fin}");
    let stats = mux.submit(&ApiRequest::Stats).unwrap().wait_done().unwrap();
    assert_eq!(stats.get("deadline_expired").as_i64(), Some(1), "{stats}");
    server.request_stop();
}

#[test]
fn v3_slow_reader_stream_does_not_stall_other_requests() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);

    // raw socket: submit a long STREAM plus three quick generates, then
    // read NOTHING for a while (slow client). The server must keep all
    // four advancing into its outbound buffer; the quick finals must
    // arrive BEFORE the stream's final even though the stream was
    // submitted first.
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(
        w,
        r#"{{"v":3,"tag":1,"op":"generate","prompt":"the ox runs. ","n_gen":40,"stream":true}}"#
    )
    .unwrap();
    for tag in 2..=4 {
        writeln!(
            w,
            r#"{{"v":3,"tag":{tag},"op":"generate","prompt":"the fox hides. ","n_gen":2}}"#
        )
        .unwrap();
    }
    std::thread::sleep(std::time::Duration::from_millis(300));
    let mut final_order = Vec::new();
    let mut stream_frames = 0usize;
    while final_order.len() < 4 {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "early EOF");
        let v = asymkv::util::json::parse(line.trim()).unwrap();
        let tag = v.get("tag").as_i64().unwrap();
        if v.get("done").as_bool() == Some(true) {
            final_order.push(tag);
        } else {
            assert_eq!(tag, 1, "only tag 1 streams: {v}");
            stream_frames += 1;
        }
    }
    assert_eq!(stream_frames, 40, "one frame per streamed token");
    assert_eq!(
        final_order.last(),
        Some(&1),
        "quick requests must finish ahead of the long stream: {final_order:?}"
    );
    assert_eq!(final_order.len(), 4);
    server.request_stop();
}

#[test]
fn v3_too_many_inflight_is_typed_error() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot_with(coord, |s| s.max_inflight = 2);
    let mux = MuxClient::connect(&addr).unwrap();
    let a = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "the ox runs. ".into(),
            n_gen: 32,
            ..Default::default()
        }))
        .unwrap();
    let b = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "the fox hides. ".into(),
            n_gen: 32,
            ..Default::default()
        }))
        .unwrap();
    // third concurrent submit exceeds the connection's cap
    let c = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "the hen sleeps. ".into(),
            n_gen: 2,
            ..Default::default()
        }))
        .unwrap();
    let v = c.wait_done().unwrap();
    assert_eq!(
        v.get("error").get("code").as_str(),
        Some("too_many_inflight"),
        "{v}"
    );
    // the two admitted requests are unaffected
    assert_eq!(a.wait_done().unwrap().get("tokens").as_arr().unwrap().len(), 32);
    assert_eq!(b.wait_done().unwrap().get("tokens").as_arr().unwrap().len(), 32);
    server.request_stop();
}

#[test]
fn v3_session_append_and_batch_items_stream() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mux = MuxClient::connect(&addr).unwrap();

    // streaming session turn (v3-only surface)
    let opened = mux
        .submit(&ApiRequest::SessionOpen {
            policy: Some(QuantPolicy::float32(n)),
            prefix_id: None,
        })
        .unwrap()
        .wait_done()
        .unwrap();
    let session = opened.get("session").as_i64().unwrap() as u64;
    let turn = mux
        .submit(&ApiRequest::SessionAppend {
            session,
            spec: GenerateSpec {
                prompt: "the ox runs. ".into(),
                n_gen: 4,
                stream: true,
                ..Default::default()
            },
        })
        .unwrap();
    let mut tokens = 0;
    let fin = loop {
        let f = turn.recv().unwrap();
        if f.get("done").as_bool() == Some(true) {
            break f;
        }
        assert!(f.get("token").as_i64().is_some(), "{f}");
        tokens += 1;
    };
    assert_eq!(tokens, 4, "one frame per turn token");
    assert_eq!(fin.get("turn").as_i64(), Some(1), "{fin}");
    assert_eq!(fin.get("tokens").as_arr().unwrap().len(), 4);
    mux.submit(&ApiRequest::SessionClose { session })
        .unwrap()
        .wait_done()
        .unwrap();

    // batch with one streaming item: its frames carry the item index
    let batch = mux
        .submit(&ApiRequest::BatchGenerate {
            items: vec![
                GenerateSpec {
                    prompt: "the ox runs. ".into(),
                    n_gen: 2,
                    ..Default::default()
                },
                GenerateSpec {
                    prompt: "the fox hides. ".into(),
                    n_gen: 3,
                    stream: true,
                    ..Default::default()
                },
            ],
        })
        .unwrap();
    let mut item_frames = 0;
    let fin = loop {
        let f = batch.recv().unwrap();
        if f.get("done").as_bool() == Some(true) {
            break f;
        }
        assert_eq!(f.get("item").as_i64(), Some(1), "{f}");
        item_frames += 1;
    };
    assert_eq!(item_frames, 3, "one frame per streamed item token");
    let results = fin.get("results").as_arr().unwrap();
    assert_eq!(results[0].get("tokens").as_arr().unwrap().len(), 2);
    assert_eq!(results[1].get("tokens").as_arr().unwrap().len(), 3);
    server.request_stop();
}

#[test]
fn dropped_connection_cancels_inflight_work() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let baseline = engine.pool.stats().in_use_bytes;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    {
        let mux = MuxClient::connect(&addr).unwrap();
        let _abandoned = mux
            .submit(&ApiRequest::Generate(GenerateSpec {
                prompt: "the ox runs. ".into(),
                n_gen: 120,
                ..Default::default()
            }))
            .unwrap();
        // give the server a moment to admit it mid-decode
        std::thread::sleep(std::time::Duration::from_millis(50));
        drop(mux); // client walks away without cancelling
    }
    // the reader thread's EOF cleanup cancels the orphan; its pages come
    // back within a decode step or two
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let ps = server.coord.engine().pool.stats();
        if ps.in_use_bytes == baseline && ps.n_seqs == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "orphaned request still resident: {ps:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(server.coord.metrics().cancelled >= 1);
    server.request_stop();
}

#[test]
fn housekeeping_tick_evicts_idle_sessions_without_traffic() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let server = Arc::new(
        Server::bind_with(
            coord,
            "127.0.0.1:0",
            SessionConfig {
                idle_timeout: std::time::Duration::from_millis(100),
                max_sessions: 4,
                // this test asserts the legacy hard eviction
                hibernate: None,
            },
        )
        .unwrap(),
    );
    let addr = server.local_addr();
    {
        let srv = server.clone();
        std::thread::spawn(move || srv.serve());
    }
    let mut client = Client::connect(&addr).unwrap();
    let opened = client
        .send(&ApiRequest::SessionOpen { policy: Some(QuantPolicy::float32(n)), prefix_id: None })
        .unwrap();
    assert!(opened.get("session").as_i64().is_some(), "{opened}");
    assert_eq!(server.coord.engine().pool.stats().pinned_seqs, 1);

    // NO further traffic: the housekeeping tick alone must evict the idle
    // session and release its pinned sequence (the old request-path sweep
    // would have left it resident forever on a quiet server)
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let ps = server.coord.engine().pool.stats();
        if ps.pinned_seqs == 0 && ps.n_seqs == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "idle session not evicted by housekeeping: {ps:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    assert_eq!(server.coord.metrics().sessions_evicted, 1);
    server.request_stop();
}

#[test]
fn v3_drain_completes_inflight_streams_then_refuses_new_work() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mux = MuxClient::connect(&addr).unwrap();

    // park a long streaming generate, provably in flight before draining
    let gen = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "the ox runs. the".into(),
            n_gen: 32,
            stream: true,
            ..Default::default()
        }))
        .unwrap();
    let first = gen.recv().unwrap();
    assert_ne!(first.get("done").as_bool(), Some(true), "{first}");

    // a drain with an unmeetable deadline reports drained:false (there
    // are ~31 decode steps left) but admission stays closed
    let report = mux.drain(Some(1)).unwrap().wait_done().unwrap();
    assert_eq!(report.get("error"), &Value::Null, "{report}");
    assert_eq!(report.get("drained").as_bool(), Some(false), "{report}");
    assert!(report.get("inflight").as_i64().unwrap() >= 1, "{report}");
    let refused = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "more".into(),
            n_gen: 2,
            ..Default::default()
        }))
        .unwrap()
        .wait_done()
        .unwrap();
    assert_eq!(
        refused.get("error").get("code").as_str(),
        Some("draining"),
        "{refused}"
    );

    // an open-ended drain quiesces: it must block until the in-flight
    // stream finishes, then report success
    let report = mux.drain(None).unwrap().wait_done().unwrap();
    assert_eq!(report.get("error"), &Value::Null, "{report}");
    assert_eq!(report.get("drained").as_bool(), Some(true), "{report}");
    assert_eq!(report.get("inflight").as_i64(), Some(0), "{report}");

    // ZERO dropped frames: the victim stream delivered every token and
    // its final frame even though the drain completed around it
    let fin = gen.wait_done().unwrap();
    assert_eq!(fin.get("error"), &Value::Null, "{fin}");
    assert_eq!(fin.get("tokens").as_arr().unwrap().len(), 32, "{fin}");

    // instant ops stay admissible on the drained server (clients need
    // stats/close to wind down); generation stays refused
    let stats = mux.submit(&ApiRequest::Stats).unwrap().wait_done().unwrap();
    assert_eq!(stats.get("error"), &Value::Null, "{stats}");
    assert_eq!(stats.get("inflight").as_i64(), Some(0), "{stats}");
    let refused = mux
        .submit(&ApiRequest::Generate(GenerateSpec {
            prompt: "still refused".into(),
            n_gen: 2,
            ..Default::default()
        }))
        .unwrap()
        .wait_done()
        .unwrap();
    assert_eq!(
        refused.get("error").get("code").as_str(),
        Some("draining"),
        "{refused}"
    );

    // the successful drain already stopped the accept loop; this must
    // stay a harmless no-op
    server.request_stop();
}

#[test]
fn strict_v2_rejects_drain_op() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let (server, addr) = boot(coord);
    let mut client = Client::connect(&addr).unwrap();
    let v = client
        .call(&Value::obj(vec![
            ("v", Value::num(2.0)),
            ("op", Value::str_of("drain")),
        ]))
        .unwrap();
    assert_eq!(v.get("error").get("code").as_str(), Some("unknown_op"), "{v}");
    assert!(
        v.get("error").get("message").as_str().unwrap().contains("v3"),
        "the rejection must point at the v3 framing: {v}"
    );
    // and the v2 connection is still healthy afterwards
    let pong = client.send(&ApiRequest::Ping).unwrap();
    assert_eq!(pong.get("error"), &Value::Null, "{pong}");
    server.request_stop();
}
