//! Full-stack test: TCP server → coordinator → engine → artifacts.

mod common;

use std::sync::Arc;

use asymkv::coordinator::{Coordinator, CoordinatorConfig, Request};
use asymkv::model::ByteTokenizer;
use asymkv::quant::QuantPolicy;
use asymkv::server::{Client, Server};
use asymkv::util::json::Value;

#[test]
fn coordinator_roundtrip_and_batching() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_active: 8,
            max_batch: 4,
            batch_window: std::time::Duration::from_millis(5),
            prefix_cache_bytes: 0,
        },
    );
    let tok = ByteTokenizer;
    // several concurrent requests with mixed policies — the scheduler must
    // group policy-homogeneous batches and still answer everyone
    let mut handles = Vec::new();
    for i in 0..6u64 {
        let policy = if i % 2 == 0 {
            QuantPolicy::kivi(n, 2)
        } else {
            QuantPolicy::float32(n)
        };
        let mut rng = asymkv::util::rng::SplitMix::new(i);
        let ep = asymkv::workload::tasks::recall_episode(&mut rng, 3);
        handles.push(coord.submit(Request::greedy(
            i,
            tok.encode(&ep.prompt),
            5,
            policy,
        )));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait();
        assert!(resp.error.is_none(), "req {i}: {:?}", resp.error);
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.timing.total_s > 0.0);
    }
    let m = coord.metrics();
    assert_eq!(m.requests_completed, 6);
    assert_eq!(m.requests_failed, 0);
    assert!(m.tokens_generated >= 30);
    coord.shutdown();
}

#[test]
fn stop_token_terminates_early() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let tok = ByteTokenizer;
    let mut req = Request::greedy(
        1,
        tok.encode_str("the ox runs. "),
        64,
        QuantPolicy::float32(n),
    );
    // stop on space — guaranteed to appear early in this corpus
    req.stop_token = Some(b' ' as i32);
    let resp = coord.submit_wait(req);
    assert!(resp.error.is_none());
    assert!(resp.tokens.len() < 64, "stop token must cut generation short");
    assert_eq!(*resp.tokens.last().unwrap(), b' ' as i32);
    coord.shutdown();
}

#[test]
fn tcp_server_end_to_end() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let server = Arc::new(Server::bind(coord, "127.0.0.1:0").unwrap());
    let addr = server.local_addr();
    let stop = server.stop_flag();
    let srv = server.clone();
    let t = std::thread::spawn(move || srv.serve());

    let mut client = Client::connect(&addr).unwrap();
    // ping
    let pong = client
        .call(&Value::obj(vec![("op", Value::str_of("ping"))]))
        .unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    // generate
    let reply = client
        .call(&Value::obj(vec![
            ("op", Value::str_of("generate")),
            ("prompt", Value::str_of("## ABC:1234 ## ABC:")),
            ("n_gen", Value::num(4.0)),
            ("policy", Value::str_of("kivi-2")),
        ]))
        .unwrap();
    assert!(reply.get("error").as_str().is_none(), "{reply}");
    assert_eq!(reply.get("tokens").as_arr().unwrap().len(), 4);
    assert!(reply.get("total_s").as_f64().unwrap() > 0.0);
    // stats + pool introspection
    let stats = client
        .call(&Value::obj(vec![("op", Value::str_of("stats"))]))
        .unwrap();
    assert!(stats.get("requests_completed").as_i64().unwrap() >= 1);
    let pool = client
        .call(&Value::obj(vec![("op", Value::str_of("pool"))]))
        .unwrap();
    assert!(pool.get("peak_bytes").as_f64().unwrap() > 0.0);
    // malformed line → error object, connection stays usable
    let err = client.call(&Value::str_of("not an object")).unwrap();
    assert!(err.get("error").as_str().is_some());
    let pong2 = client
        .call(&Value::obj(vec![("op", Value::str_of("ping"))]))
        .unwrap();
    assert_eq!(pong2.get("ok").as_bool(), Some(true));

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = t.join().unwrap();
}

#[test]
fn unsupported_policy_rejected_cleanly() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    // 8-bit variants were never lowered — must fail the request, not wedge
    let resp = coord.submit_wait(Request::greedy(
        1,
        vec![65, 66],
        2,
        QuantPolicy::kivi(n, 8),
    ));
    assert!(resp.error.is_some());
    let m = coord.metrics();
    assert_eq!(m.requests_failed, 1);
    coord.shutdown();
}

#[test]
fn backpressure_under_tiny_pool_budget() {
    // pool sized for ~2 float sequences: 8 concurrent requests must still
    // all complete via queueing + requeue on BudgetExceeded
    let Some(dir) = common::artifact_dir("tiny") else { return };
    let rt = Arc::new(asymkv::runtime::Runtime::load(dir).unwrap());
    let probe = asymkv::engine::Engine::new(rt.clone(), usize::MAX).unwrap();
    let n = probe.manifest().n_layers;
    let one = {
        let id = probe
            .create_seq(&QuantPolicy::float32(n))
            .unwrap();
        let b = probe.with_seq(id, |s| s.capacity_bytes()).unwrap();
        probe.free_seq(id).unwrap();
        b
    };
    drop(probe);
    let engine =
        Arc::new(asymkv::engine::Engine::new(rt, one * 2 + one / 2).unwrap());
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_active: 8,
            max_batch: 4,
            batch_window: std::time::Duration::from_millis(1),
            prefix_cache_bytes: 0,
        },
    );
    let tok = ByteTokenizer;
    let handles: Vec<_> = (0..8u64)
        .map(|i| {
            let mut rng = asymkv::util::rng::SplitMix::new(i);
            let ep = asymkv::workload::tasks::recall_episode(&mut rng, 2);
            coord.submit(Request::greedy(
                i,
                tok.encode(&ep.prompt),
                3,
                QuantPolicy::float32(n),
            ))
        })
        .collect();
    for h in handles {
        let r = h.wait();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 3);
    }
    assert_eq!(coord.metrics().requests_completed, 8);
    // all caches released
    assert_eq!(coord.engine().pool.stats().n_seqs, 0);
    coord.shutdown();
}

#[test]
fn priority_ordering_respected() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    // single-slot coordinator: strictly serial execution exposes ordering
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            max_active: 1,
            max_batch: 1,
            batch_window: std::time::Duration::from_millis(30),
            prefix_cache_bytes: 0,
        },
    );
    let tok = ByteTokenizer;
    let order = Arc::new(std::sync::Mutex::new(Vec::new()));
    let mut handles = vec![];
    // submit low-priority first, then high — within the batch window both
    // are queued, and the high-priority one must run first
    for (id, prio) in [(1u64, 0i32), (2, 5), (3, 5), (4, 0)] {
        let mut req = Request::greedy(
            id,
            tok.encode_str("the ox runs. the"),
            2,
            QuantPolicy::float32(n),
        );
        req.priority = prio;
        let h = coord.submit(req);
        let order = order.clone();
        handles.push(std::thread::spawn(move || {
            let r = h.wait();
            assert!(r.error.is_none());
            order.lock().unwrap().push(id);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let got = order.lock().unwrap().clone();
    // high-priority ids (2, 3) complete before low-priority (1, 4)
    let pos = |id: u64| got.iter().position(|&x| x == id).unwrap();
    assert!(pos(2) < pos(1) && pos(2) < pos(4), "order {got:?}");
    assert!(pos(3) < pos(1) && pos(3) < pos(4), "order {got:?}");
    coord.shutdown();
}

#[test]
fn shutdown_drains_in_flight_work() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let tok = ByteTokenizer;
    let h = coord.submit(Request::greedy(
        1,
        tok.encode_str("## AAB:1290 ## AAB:"),
        4,
        QuantPolicy::kivi(n, 2),
    ));
    coord.shutdown(); // must not drop the in-flight request
    let r = h.wait();
    assert!(r.error.is_none());
    assert_eq!(r.tokens.len(), 4);
}

#[test]
fn oversized_request_fails_fast_not_livelock() {
    // a request whose cache alone exceeds the TOTAL budget must be failed,
    // not requeued forever
    let Some(dir) = common::artifact_dir("tiny") else { return };
    let rt = Arc::new(asymkv::runtime::Runtime::load(dir).unwrap());
    let engine = Arc::new(asymkv::engine::Engine::new(rt, 1024).unwrap()); // 1 KiB
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let resp = coord.submit_wait(Request::greedy(
        1,
        vec![65, 66, 67],
        2,
        QuantPolicy::float32(n),
    ));
    assert!(resp.error.is_some(), "must fail, not hang");
    assert!(resp.error.unwrap().contains("admission failed"));
    coord.shutdown();
}

#[test]
fn streaming_generate_emits_token_lines() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let server = Arc::new(Server::bind(coord, "127.0.0.1:0").unwrap());
    let addr = server.local_addr();
    let stop = server.stop_flag();
    {
        let srv = server.clone();
        std::thread::spawn(move || srv.serve());
    }
    // raw client: one request line, then read until "done":true
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(
        w,
        r#"{{"op":"generate","prompt":"the ox runs. ","n_gen":5,"stream":true,"policy":"kivi-2"}}"#
    )
    .unwrap();
    let mut pieces = Vec::new();
    let mut final_tokens = None;
    for _ in 0..64 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = asymkv::util::json::parse(line.trim()).unwrap();
        if v.get("done").as_bool() == Some(true) {
            assert!(v.get("error").as_str().is_none(), "{v}");
            final_tokens = Some(v.get("tokens").as_arr().unwrap().len());
            break;
        }
        pieces.push(v.get("token").as_i64().unwrap());
    }
    assert_eq!(final_tokens, Some(5));
    assert_eq!(pieces.len(), 5, "one streamed line per token");
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
}

#[test]
fn prefix_cache_accelerates_shared_prompts() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(
        engine,
        CoordinatorConfig {
            prefix_cache_bytes: 64 << 20,
            ..Default::default()
        },
    );
    let tok = ByteTokenizer;
    let prompt = "## AAB:1290 ZZT:4456 ## ZZT:";
    // same prompt three times: 2nd/3rd hit the snapshot
    let mut outs = Vec::new();
    for i in 0..3u64 {
        let r = coord.submit_wait(Request::greedy(
            i,
            tok.encode_str(prompt),
            4,
            QuantPolicy::kivi(n, 2),
        ));
        assert!(r.error.is_none());
        outs.push(r.tokens);
    }
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[1], outs[2]);
    let ps = coord.prefix_stats().unwrap();
    assert!(ps.hits >= 2, "prefix stats {ps:?}");
    assert!(ps.entries >= 1);
    coord.shutdown();
}
