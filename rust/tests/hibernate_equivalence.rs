//! Hibernate → restore → decode must be bit-identical to never having
//! hibernated.
//!
//! The restored fold schedule depends only on the logical `(n_q, n_res)`
//! counts, so a spilled-and-restored session's cache reads — full
//! dequantization AND the fused decode-attention path — must equal the
//! donor's exactly, and must KEEP equaling it as further turns append
//! (the interleaved-turns half of the property). Random per-layer bit
//! policies (the 1-bit flagship, mixed asymmetric configs, fp32 layers)
//! and random residual-ring fills, via `util::prop`.
//!
//! The first properties are artifact-free (raw codec + store on
//! synthetic caches). The final test drives the REAL `SessionManager`
//! over a live engine — turn, idle sweep (spill), turn (restore) — and
//! asserts the greedy continuation equals a never-hibernated session's;
//! it self-skips when `artifacts/tiny` is not built.

mod common;

use std::path::PathBuf;
use std::time::Duration;

use asymkv::api::{GenerateSpec, SessionConfig, SessionManager};
use asymkv::coordinator::{Coordinator, CoordinatorConfig};
use asymkv::kvcache::hibernate::{decode, encode};
use asymkv::kvcache::{
    CacheGeometry, HibernateConfig, HibernateError, HibernateStore,
    LayerCache, SeqBase, SeqCache,
};
use asymkv::quant::QuantPolicy;
use asymkv::util::prop::{check, Gen};

const GEO: CacheGeometry = CacheGeometry {
    n_heads: 2,
    max_ctx: 512,
    d_head: 32,
    group: 32,
    residual: 64,
};

/// The policy space: flagship 1-bit, asymmetric mixes, and fp32 layers.
const BITS: &[(u8, u8)] = &[
    (0, 0),
    (0, 1),
    (1, 0),
    (1, 1),
    (1, 2),
    (2, 1),
    (2, 2),
    (4, 4),
];

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("asymkv-hibeq-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A donor cache with `layer_bits` layers and `n` appended random tokens.
fn donor(g: &mut Gen, layer_bits: &[(u8, u8)], n: usize) -> SeqCache {
    let hd = GEO.n_heads * GEO.d_head;
    let layers = layer_bits
        .iter()
        .map(|&(kb, vb)| LayerCache::new(GEO, kb, vb))
        .collect();
    let mut seq = SeqCache { layers, pos: 0, base: None, cow_noted: false };
    for _ in 0..n {
        for l in seq.layers.iter_mut() {
            let k = g.vec_normal(hd, 1.0);
            let v = g.vec_normal(hd, 1.0);
            l.append_token(&k, &v);
        }
        seq.pos += 1;
    }
    seq
}

/// Every cache read the decode path performs must match exactly.
fn caches_equal(
    a: &SeqCache,
    b: &SeqCache,
    queries: &[Vec<f32>],
    when: &str,
) -> Result<(), String> {
    if a.pos != b.pos {
        return Err(format!("{when}: pos {} != {}", a.pos, b.pos));
    }
    for (li, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate() {
        if la.n_tokens() != lb.n_tokens() {
            return Err(format!(
                "{when}: layer {li} n_tokens {} != {}",
                la.n_tokens(),
                lb.n_tokens()
            ));
        }
        if la.dequant_k_full() != lb.dequant_k_full() {
            return Err(format!("{when}: layer {li} K dequant differs"));
        }
        if la.dequant_v_full() != lb.dequant_v_full() {
            return Err(format!("{when}: layer {li} V dequant differs"));
        }
        // the fused decode-attention path (scores + weighted output) —
        // this is what "decode-bit-identical" means at the kernel level
        for (h, q) in queries.iter().enumerate() {
            if la.attend_head(h, q) != lb.attend_head(h, q) {
                return Err(format!(
                    "{when}: layer {li} head {h} attention differs"
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn restore_then_decode_is_bit_identical_across_policies() {
    check("hibernate_restore_bit_identical", 48, |g| {
        let n_layers = g.usize_in(1, 4);
        let layer_bits: Vec<(u8, u8)> =
            (0..n_layers).map(|_| *g.pick(BITS)).collect();
        // random residual-ring fill: spans empty, partial, fold-boundary
        // and multi-fold token counts (group 32, residual 64)
        let n = g.usize_in(0, 120);
        let mut live = donor(g, &layer_bits, n);

        let frozen = SeqBase::freeze(&live);
        let img = decode(&encode(&frozen, "fp")).map_err(|e| e.to_string())?;
        let mut restored = img.into_seq();

        let queries: Vec<Vec<f32>> = (0..GEO.n_heads)
            .map(|_| g.vec_normal(GEO.d_head, 1.0))
            .collect();
        caches_equal(&live, &restored, &queries, "after restore")?;

        // interleaved turns: the SAME continuation appended to both must
        // keep them identical through folds and ring wraps
        let hd = GEO.n_heads * GEO.d_head;
        let extra = g.usize_in(1, 40);
        for _ in 0..extra {
            let toks: Vec<(Vec<f32>, Vec<f32>)> = (0..n_layers)
                .map(|_| (g.vec_normal(hd, 1.0), g.vec_normal(hd, 1.0)))
                .collect();
            for seq in [&mut live, &mut restored] {
                for (l, (k, v)) in seq.layers.iter_mut().zip(toks.iter()) {
                    l.append_token(k, v);
                }
                seq.pos += 1;
            }
        }
        caches_equal(&live, &restored, &queries, "after interleaved turns")
    });
}

#[test]
fn random_corruption_is_always_typed_never_a_panic() {
    check("hibernate_corruption_typed", 40, |g| {
        let layer_bits: Vec<(u8, u8)> =
            (0..g.usize_in(1, 3)).map(|_| *g.pick(BITS)).collect();
        let seq = donor(g, &layer_bits, g.usize_in(1, 80));
        let good = encode(&SeqBase::freeze(&seq), "fp");
        let mode = g.usize_in(0, 2);
        let bad = match mode {
            0 => {
                // flip one random byte anywhere (checksum bytes included)
                let mut b = good.clone();
                let off = g.usize_in(0, b.len() - 1);
                b[off] ^= 1 << g.usize_in(0, 7);
                b
            }
            1 => {
                // truncate at a random point
                good[..g.usize_in(0, good.len() - 1)].to_vec()
            }
            _ => {
                // append trailing garbage
                let mut b = good.clone();
                b.extend_from_slice(&[0xAA; 7]);
                b
            }
        };
        match decode(&bad) {
            Err(HibernateError::Corrupt(_)) => Ok(()),
            Ok(_) => Err(format!("mode {mode}: corrupt image decoded")),
            Err(e) => Err(format!("mode {mode}: wrong error {e:?}")),
        }
    });
}

#[test]
fn store_roundtrip_through_files_preserves_equivalence() {
    let dir = tmp_dir("store");
    let store = HibernateStore::new(HibernateConfig {
        dir: dir.clone(),
        budget_bytes: 256 << 20,
    })
    .unwrap();
    check("hibernate_store_roundtrip", 12, |g| {
        let n_layers = g.usize_in(1, 3);
        let layer_bits: Vec<(u8, u8)> =
            (0..n_layers).map(|_| *g.pick(BITS)).collect();
        let live = donor(g, &layer_bits, g.usize_in(0, 100));
        let sid = g.usize_in(1, 1 << 20) as u64;
        store
            .spill(sid, &SeqBase::freeze(&live), "fp")
            .map_err(|e| e.to_string())?;
        let img = store.restore(sid).map_err(|e| e.to_string())?;
        if img.fingerprint != "fp" {
            return Err("fingerprint lost through the file".into());
        }
        let restored = img.into_seq();
        let queries: Vec<Vec<f32>> = (0..GEO.n_heads)
            .map(|_| g.vec_normal(GEO.d_head, 1.0))
            .collect();
        let res = caches_equal(&live, &restored, &queries, "via store");
        store.discard(sid);
        res
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn budget_reclaim_surfaces_typed_on_restore() {
    let dir = tmp_dir("reclaim");
    let mut g = Gen { rng: asymkv::util::rng::SplitMix::new(0x5EC7) };
    let live = donor(&mut g, &[(1, 1), (1, 1)], 96);
    let frozen = SeqBase::freeze(&live);
    let image_len = encode(&frozen, "fp").len();
    // budget holds exactly two images: the third spill reclaims the LRU
    let store = HibernateStore::new(HibernateConfig {
        dir: dir.clone(),
        budget_bytes: 2 * image_len,
    })
    .unwrap();
    store.spill(1, &frozen, "fp").unwrap();
    store.spill(2, &frozen, "fp").unwrap();
    store.spill(3, &frozen, "fp").unwrap();
    assert!(
        matches!(store.restore(1), Err(HibernateError::Reclaimed(1))),
        "LRU victim must fail restore with the typed Reclaimed error"
    );
    // survivors restore to full equivalence
    for sid in [2u64, 3] {
        let restored = store.restore(sid).unwrap().into_seq();
        let queries: Vec<Vec<f32>> = (0..GEO.n_heads)
            .map(|_| g.vec_normal(GEO.d_head, 1.0))
            .collect();
        caches_equal(&live, &restored, &queries, "survivor").unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end over a real engine: session → turn → idle sweep (spill) →
/// turn (restore) must produce the same greedy continuation as a session
/// that never hibernated. Skips without artifacts.
#[test]
fn hibernated_session_continues_greedy_identical() {
    let Some(engine) = common::engine_for("tiny") else { return };
    let n = engine.manifest().n_layers;
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let dir = tmp_dir("session");
    let mgr = SessionManager::new(
        coord.clone(),
        SessionConfig {
            idle_timeout: Duration::from_millis(30),
            max_sessions: 8,
            hibernate: Some(HibernateConfig {
                dir: dir.clone(),
                budget_bytes: 256 << 20,
            }),
        },
    );
    let policy = QuantPolicy::kivi(n, 1); // the 1-bit flagship
    let turn1 = GenerateSpec {
        prompt: "## ABC:1234 QRS:5678 ## ".into(),
        n_gen: 8,
        ..Default::default()
    };
    let turn2 = GenerateSpec {
        prompt: "ABC:".into(),
        n_gen: 8,
        ..Default::default()
    };

    // path A: turn, idle past the sweep threshold, spill, restore, turn
    let (sa, _) = mgr.open(Some(policy.clone()), None).unwrap();
    let a1 = mgr.append(sa, 1, &turn1).unwrap();
    assert!(a1.result.error.is_none(), "{:?}", a1.result.error);
    std::thread::sleep(Duration::from_millis(60));
    mgr.sweep_idle();
    let rep = mgr.hibernate_report().expect("hibernation is configured");
    assert!(rep.spills >= 1, "idle sweep did not spill: {rep:?}");
    assert_eq!(mgr.len(), 1, "hibernated session must stay open");
    let a2 = mgr.append(sa, 2, &turn2).unwrap();
    assert!(a2.result.error.is_none(), "{:?}", a2.result.error);
    let rep = mgr.hibernate_report().unwrap();
    assert!(rep.restores >= 1, "turn 2 did not restore: {rep:?}");

    // path B: the same two turns back-to-back, never hibernated
    let (sb, _) = mgr.open(Some(policy), None).unwrap();
    let b1 = mgr.append(sb, 3, &turn1).unwrap();
    let b2 = mgr.append(sb, 4, &turn2).unwrap();

    assert_eq!(
        a1.result.tokens, b1.result.tokens,
        "turn 1 must not depend on hibernation at all"
    );
    assert_eq!(
        a2.result.tokens, b2.result.tokens,
        "greedy continuation after restore must be bit-identical \
         to the never-hibernated session"
    );
    assert_eq!(a2.pos, b2.pos, "restored position drifted");

    mgr.close(sa).unwrap();
    mgr.close(sb).unwrap();
    coord.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
