//! End-to-end calibration pipeline, artifact-free and deterministic: a
//! seeded synthetic sensitivity profile drives the budget solver against a
//! real manifest fixture (loaded through `Manifest::load`, grid and all),
//! the derived `AsymKV-auto@…` policy round-trips through the policy
//! grammar and the registry, and a live `LayerCache` downshifts in place
//! to the solved widths. This is the whole profile → solve → serve →
//! downshift chain with no compiled artifacts — the server-level
//! `calibrate` op is the same pipeline behind the wire protocol.

use std::path::PathBuf;

use asymkv::calib::{profile_synthetic, solve_for_manifest, PolicyRegistry};
use asymkv::kvcache::LayerCache;
use asymkv::model::Manifest;
use asymkv::quant::QuantPolicy;
use asymkv::util::prop::Gen;
use asymkv::util::rng::SplitMix;

fn fixture_manifest() -> Manifest {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("calib_tiny");
    Manifest::load(dir).expect("loading calib_tiny fixture manifest")
}

/// Candidate widths exactly as the server derives them: every nonzero bit
/// the manifest's grid can execute.
fn grid_bits(m: &Manifest) -> Vec<u8> {
    let mut bits: Vec<u8> =
        m.grid.iter().flat_map(|&(k, v)| [k, v]).filter(|&b| b != 0).collect();
    bits.sort_unstable();
    bits.dedup();
    bits
}

fn fixture_profile(m: &Manifest, seed: u64) -> asymkv::calib::SensitivityProfile {
    profile_synthetic(m.n_layers, m.n_heads, m.d_head, m.group, 96, seed, &grid_bits(m))
}

#[test]
fn solved_policy_fits_budget_and_round_trips() {
    let m = fixture_manifest();
    assert_eq!(grid_bits(&m), vec![1, 2]);
    let profile = fixture_profile(&m, 7);
    let floor =
        QuantPolicy::kivi(m.n_layers, 1).bytes_per_token(m.n_heads, m.d_head, m.group);
    let budget = floor + 16;
    let s = solve_for_manifest(&profile, &m, budget).unwrap();

    assert!(s.bytes_per_token <= budget, "{} > budget {budget}", s.bytes_per_token);
    assert!(
        s.policy.name.starts_with("AsymKV-auto@"),
        "unexpected policy name '{}'",
        s.policy.name
    );
    // grid-supported and grammar-round-trippable: a client can paste the
    // reported name into any generate line
    m.supports_policy(&s.policy).unwrap();
    let parsed = QuantPolicy::parse(&s.policy.name, m.n_layers).unwrap();
    assert_eq!(parsed, s.policy);

    // same profile seed + budget → byte-identical policy
    let again = solve_for_manifest(&fixture_profile(&m, 7), &m, budget).unwrap();
    assert_eq!(again.policy, s.policy);

    // serve step: registered policies list and resolve by exact name
    let reg = PolicyRegistry::new();
    reg.register(s.policy.clone());
    assert_eq!(reg.list(), vec![s.policy.name.clone()]);
    assert_eq!(reg.resolve(&s.policy.name, m.n_layers).unwrap(), s.policy);
}

#[test]
fn lavish_budget_solves_to_float_and_tight_budget_to_one_bit() {
    let m = fixture_manifest();
    let profile = fixture_profile(&m, 11);
    let lavish = solve_for_manifest(&profile, &m, usize::MAX).unwrap();
    assert_eq!(lavish.predicted_damage, 0.0);
    assert!(lavish.policy.k_bits.iter().chain(&lavish.policy.v_bits).all(|&b| b == 0));

    let floor =
        QuantPolicy::kivi(m.n_layers, 1).bytes_per_token(m.n_heads, m.d_head, m.group);
    let tight = solve_for_manifest(&profile, &m, floor).unwrap();
    assert!(tight.policy.k_bits.iter().chain(&tight.policy.v_bits).all(|&b| b == 1));
    assert!(solve_for_manifest(&profile, &m, floor - 1).is_err(), "sub-floor budget");
}

#[test]
fn live_cache_downshifts_in_place_to_solved_widths() {
    let m = fixture_manifest();
    let profile = fixture_profile(&m, 5);
    let floor =
        QuantPolicy::kivi(m.n_layers, 1).bytes_per_token(m.n_heads, m.d_head, m.group);
    let s = solve_for_manifest(&profile, &m, floor).unwrap();

    // a cache running the grid's widest quantized pair, filled far enough
    // that cold folded groups exist (the region the downshift re-packs)
    let geo = m.geometry();
    let hd = geo.n_heads * geo.d_head;
    let n = geo.max_ctx; // 128 tokens: 64 fold, 64 stay in the residual ring
    let mut g = Gen { rng: SplitMix::new(3) };
    let ks = g.vec_normal(n * hd, 1.0);
    let vs = g.vec_normal(n * hd, 1.0);
    let mut lc = LayerCache::new(geo, 2, 2);
    lc.append_tokens(n, &ks, &vs);

    let before = lc.capacity_bytes();
    let freed = lc.downshift_groups(s.policy.k_bits[0], s.policy.v_bits[0]);
    assert!(freed > 0, "2-bit → 1-bit downshift must shrink the packed region");
    assert_eq!(before - lc.capacity_bytes(), freed, "freed must match the delta");
    assert_eq!(lc.n_tokens(), n, "downshift must not drop tokens");
}
