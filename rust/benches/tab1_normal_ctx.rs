//! Table 1 — Evaluation on tasks with normal context length.
//!
//! Paper rows: float / KIVI-2bit / AsymKV-0/l / AsymKV-l/0 at l = half the
//! layers (16 of 32 for Llama-7b), scored on TruthfulQA + CoQA. Expected
//! shape: AsymKV-l/0 (high-bit KEYS) ≫ AsymKV-0/l at the same memory, and
//! AsymKV-l/0 within 90 % of float.
//!
//! Here (DESIGN.md §1): the pretrained `small` model (8 layers → l = 4),
//! scored on recall-QA accuracy (↔ CoQA extractive answers) and held-out
//! perplexity (↔ TruthfulQA likelihood scoring), ctx ≤ 256.

use std::sync::Arc;

use asymkv::engine::Engine;
use asymkv::evals;
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::workload::{self, tasks};

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();
    let n = m.n_layers;
    let l = n / 2;

    let suite = tasks::recall_suite(0x7AB1, 24, 12);
    let docs: Vec<Vec<u8>> = (0..6)
        .map(|i| workload::eval_doc(1, i, m.max_ctx - m.chunk))
        .collect();

    note("tab1_normal_ctx", &format!(
        "\nTable 1 reproduction — model {}, {} recall episodes (12 pairs, ≈120 tokens — past the fp32 residual window), \
         {} ppl docs, l = {l} of {n} layers \
         (paper: Llama-2-7b/13b, TruthfulQA + CoQA, l = 16/20 of 32/40)",
        m.name, suite.len(), docs.len()));

    let mut t = Table::new(
        "Tab.1: normal-context quality",
        &["type", "recall acc ↑", "ppl ↓", "≥90% float?"],
    );
    let mut float_acc = 0.0;
    for policy in evals::table_policies(n, l) {
        let acc = evals::recall_accuracy(&engine, &policy, &suite)?;
        let ppl = evals::perplexity(&engine, &policy, &docs)?;
        if policy.name == "float" {
            float_acc = acc;
        }
        let star = if evals::meets_90pct(acc, float_acc) { "*" } else { "" };
        t.row(vec![
            policy.name.clone(),
            format!("{acc:.3}"),
            format!("{ppl:.2}"),
            star.to_string(),
        ]);
    }
    t.emit("tab1_normal_ctx");
    note("tab1_normal_ctx",
         "\nPaper shape: AsymKV-l/0 (keys high) must beat AsymKV-0/l \
          (values high) at identical memory, and reach ≥90 % of float (*).");
    Ok(())
}
