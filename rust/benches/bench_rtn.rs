//! Kernel microbench: contiguous quantize / pack / unpack, scalar vs
//! wordpack, across bits ∈ {1, 2, 4, 8}. Pure-Rust (no artifacts needed),
//! so it runs everywhere including CI's bench-smoke job. Emits the
//! `rtn_*` records of `BENCH_kernels.json` (schema: docs/BENCH.md).

use asymkv::quant::kernels::{self, KernelMode};
use asymkv::util::bench::{self, fmt_duration, fmt_throughput, time_fn, JsonReport, Table};
use asymkv::util::json::Value;
use asymkv::util::rng::SplitMix;

const MODES: [(KernelMode, &str); 2] =
    [(KernelMode::Scalar, "scalar"), (KernelMode::Wordpack, "wordpack")];

fn main() {
    let n: usize = if bench::smoke() { 4096 } else { 1 << 16 };
    let reps = bench::samples(300);
    let warm = bench::warmup(20);
    let mut rng = SplitMix::new(0xBE9C);
    let xs: Vec<f32> = rng.normal_f32_vec(n);

    bench::note(
        "bench_rtn",
        &format!("\nRTN contiguous kernels — n={n} values, {reps} samples"),
    );
    let mut t = Table::new(
        "quantize / pack / unpack (per call over n values)",
        &["op", "bits", "impl", "p50", "throughput"],
    );
    let mut report = JsonReport::at_root("BENCH_kernels.json");

    for bits in [1u8, 2, 4, 8] {
        let codes: Vec<u8> =
            (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
        let mut packed = vec![0u8; kernels::packed_len(n, bits)];
        let mut out_codes = vec![0u8; n];
        let mut out_f32 = vec![0f32; n];

        // quantize (shared min-max + rounding path; mode-dispatched)
        for (mode, name) in MODES {
            let tm = time_fn(warm, reps, || {
                let p = kernels::quantize_group_with(mode, &xs, bits, &mut out_codes);
                std::hint::black_box(p);
            });
            let cfg = config(bits, name, n);
            t.row(vec![
                "quantize".into(),
                bits.to_string(),
                name.into(),
                fmt_duration(tm.p50()),
                fmt_throughput(n as f64 * 4.0 / tm.mean()),
            ]);
            report.add(&format!("rtn_quantize_bits{bits}_{name}"), &tm, n * 4, cfg);
        }

        // pack
        for (mode, name) in MODES {
            let tm = time_fn(warm, reps, || {
                kernels::pack_bits_with(mode, &codes, bits, &mut packed);
                std::hint::black_box(&packed);
            });
            t.row(vec![
                "pack".into(),
                bits.to_string(),
                name.into(),
                fmt_duration(tm.p50()),
                fmt_throughput(n as f64 / tm.mean()),
            ]);
            report.add(&format!("rtn_pack_bits{bits}_{name}"), &tm, n, config(bits, name, n));
        }

        // unpack
        for (mode, name) in MODES {
            let tm = time_fn(warm, reps, || {
                kernels::unpack_bits_with(mode, &packed, bits, &mut out_codes);
                std::hint::black_box(&out_codes);
            });
            t.row(vec![
                "unpack".into(),
                bits.to_string(),
                name.into(),
                fmt_duration(tm.p50()),
                fmt_throughput(n as f64 / tm.mean()),
            ]);
            report.add(&format!("rtn_unpack_bits{bits}_{name}"), &tm, n, config(bits, name, n));
        }

        // dequantize (identical code both modes; one record)
        let p = kernels::quantize_group(&xs, bits, &mut out_codes);
        let tm = time_fn(warm, reps, || {
            kernels::dequantize_group(&out_codes, p, &mut out_f32);
            std::hint::black_box(&out_f32);
        });
        t.row(vec![
            "dequantize".into(),
            bits.to_string(),
            "shared".into(),
            fmt_duration(tm.p50()),
            fmt_throughput(n as f64 * 4.0 / tm.mean()),
        ]);
        report.add(
            &format!("rtn_dequantize_bits{bits}"),
            &tm,
            n * 4,
            config(bits, "shared", n),
        );
    }

    t.emit("bench_rtn");
    report.write().expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json (rtn_* records)");
}

fn config(bits: u8, imp: &str, n: usize) -> Value {
    Value::obj(vec![
        ("bits", Value::num(bits as f64)),
        ("impl", Value::str_of(imp)),
        ("n", Value::num(n as f64)),
    ])
}
