//! Table 3 (appendix) — the full normal-context sweep over l for both axes.
//!
//! Paper: AsymKV-0/l and AsymKV-l/0 for l ∈ {6, 12, 16, 22} (Llama-7b) —
//! quality rises monotonically in l on both axes, with the key axis far
//! ahead at every matched-memory point.
//!
//! Here: l ∈ {1, 2, 4, 6, 8} of 8 layers on recall accuracy + perplexity.

use std::sync::Arc;

use asymkv::engine::Engine;
use asymkv::evals;
use asymkv::quant::QuantPolicy;
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::workload::{self, tasks};

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();
    let n = m.n_layers;

    let suite = tasks::recall_suite(0x7AB3, 24, 12);
    let docs: Vec<Vec<u8>> = (0..6)
        .map(|i| workload::eval_doc(3, i, m.max_ctx - m.chunk))
        .collect();

    note("tab3_normal_sweep", &format!(
        "\nTable 3 reproduction — sweep l over both axes, model {} \
         (paper: l ∈ {{6,12,16,22}} of 32)", m.name));

    let mut t = Table::new(
        "Tab.3: normal-context sweep",
        &["type", "recall acc ↑", "ppl ↓", "≥90% float?"],
    );
    let float_p = QuantPolicy::float32(n);
    let float_acc = evals::recall_accuracy(&engine, &float_p, &suite)?;
    let float_ppl = evals::perplexity(&engine, &float_p, &docs)?;
    t.row(vec!["float".into(), format!("{float_acc:.3}"),
               format!("{float_ppl:.2}"), "".into()]);
    let kivi = QuantPolicy::kivi(n, 2);
    let kacc = evals::recall_accuracy(&engine, &kivi, &suite)?;
    let kppl = evals::perplexity(&engine, &kivi, &docs)?;
    t.row(vec!["KIVI-2bit".into(), format!("{kacc:.3}"),
               format!("{kppl:.2}"), "".into()]);

    let ls = [1usize, 2, 4, 6, 8];
    for &l in &ls {
        let p = QuantPolicy::asymkv21(n, 0, l);
        let acc = evals::recall_accuracy(&engine, &p, &suite)?;
        let ppl = evals::perplexity(&engine, &p, &docs)?;
        t.row(vec![p.name.clone(), format!("{acc:.3}"), format!("{ppl:.2}"),
                   if evals::meets_90pct(acc, float_acc) { "*" } else { "" }.into()]);
    }
    let mut accs_k = Vec::new();
    for &l in &ls {
        let p = QuantPolicy::asymkv21(n, l, 0);
        let acc = evals::recall_accuracy(&engine, &p, &suite)?;
        let ppl = evals::perplexity(&engine, &p, &docs)?;
        accs_k.push(acc);
        t.row(vec![p.name.clone(), format!("{acc:.3}"), format!("{ppl:.2}"),
                   if evals::meets_90pct(acc, float_acc) { "*" } else { "" }.into()]);
    }
    t.emit("tab3_normal_sweep");

    let monotone = accs_k.windows(2).all(|w| w[1] >= w[0] - 0.05);
    note("tab3_normal_sweep", &format!(
        "\nPaper shape: accuracy rises (near-)monotonically in l_k \
         ({}) and AsymKV-l/0 dominates AsymKV-0/l at every l.",
        if monotone { "holds" } else { "VIOLATED" }));
    Ok(())
}
