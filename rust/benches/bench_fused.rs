//! Fused dequant-attention decode bench: one query row attending over a
//! packed cache, computed two ways per bit-width —
//!
//!   unfold_attn_*: the pre-fused shipping path. Per group, wordpack
//!     `unfold_k_group` into an f32 scratch then [`dot8`] per token row;
//!     softmax; wordpack `unfold_v_group` then [`weighted_acc`].
//!   fused_attn_*:  `attn_scores_k_group` / `attn_weighted_v_group`
//!     straight from packed codes + GroupParams, no materialized f32 tile.
//!
//! Both sides share the softmax and the canonical summation orders, so the
//! bench first asserts the two paths are BIT-IDENTICAL on scores and
//! output, then times them. Pure-Rust (no artifacts), runs everywhere.
//! Emits the `fused_attn_*` / `unfold_attn_*` records of
//! `BENCH_kernels.json`; the fused config carries `ratio_vs_unfold`, and
//! full (non-smoke) runs enforce the >= 1.5x floor at 1–2 bit.

use asymkv::quant::kernels::{self, GroupParams, KernelMode};
use asymkv::util::bench::{self, fmt_duration, fmt_throughput, time_fn, JsonReport, Table};
use asymkv::util::json::Value;
use asymkv::util::rng::SplitMix;

// Decode-attention shape: one query over N cached tokens, the per-head
// work of every decode step at a 4k-ish context after one GQA head.
const N: usize = 1024;
const G: usize = 32;
const DH: usize = 128;
const G2: usize = 32;
const NG: usize = N / G;

fn cfg(bits: u8, imp: &str) -> Value {
    Value::obj(vec![
        ("bits", Value::num(bits as f64)),
        ("impl", Value::str_of(imp)),
        ("n", Value::num(N as f64)),
        ("g", Value::num(G as f64)),
        ("dh", Value::num(DH as f64)),
        ("g2", Value::num(G2 as f64)),
    ])
}

/// The shared epilogue: scale by 1/sqrt(Dh), subtract max, exp, normalize.
fn softmax_inplace(s: &mut [f32]) {
    let inv = 1.0 / (DH as f32).sqrt();
    let mut max = f32::NEG_INFINITY;
    for w in s.iter_mut() {
        *w *= inv;
        if *w > max {
            max = *w;
        }
    }
    let mut denom = 0f32;
    for w in s.iter_mut() {
        *w = (*w - max).exp();
        denom += *w;
    }
    let inv_d = 1.0 / denom;
    for w in s.iter_mut() {
        *w *= inv_d;
    }
}

struct PackedCache {
    bits: u8,
    packed_k: Vec<u8>,   // [NG, rows_pk, DH]
    params_k: Vec<GroupParams>, // [NG, DH]
    packed_v: Vec<u8>,   // [NG, G, bpt]
    params_v: Vec<GroupParams>, // [NG, G, dg]
    rows_pk: usize,
    bpt: usize,
    dg: usize,
}

fn fold_cache(bits: u8, k: &[f32], v: &[f32]) -> PackedCache {
    let rows_pk = kernels::packed_len(G, bits);
    let bpt = kernels::packed_len(DH, bits);
    let dg = DH / G2;
    let mut c = PackedCache {
        bits,
        packed_k: vec![0u8; NG * rows_pk * DH],
        params_k: vec![GroupParams { scale: 0.0, zero: 0.0 }; NG * DH],
        packed_v: vec![0u8; NG * G * bpt],
        params_v: vec![GroupParams { scale: 0.0, zero: 0.0 }; NG * G * dg],
        rows_pk,
        bpt,
        dg,
    };
    for gi in 0..NG {
        kernels::fold_k_group(
            &k[gi * G * DH..(gi + 1) * G * DH],
            G,
            DH,
            bits,
            &mut c.packed_k[gi * rows_pk * DH..(gi + 1) * rows_pk * DH],
            &mut c.params_k[gi * DH..(gi + 1) * DH],
        );
        kernels::fold_v_group(
            &v[gi * G * DH..(gi + 1) * G * DH],
            G,
            DH,
            G2,
            bits,
            &mut c.packed_v[gi * G * bpt..(gi + 1) * G * bpt],
            &mut c.params_v[gi * G * dg..(gi + 1) * G * dg],
        );
    }
    c
}

/// Fused path: scores and weighted V straight from packed codes.
fn attn_fused(c: &PackedCache, q: &[f32], scores: &mut [f32], out: &mut [f32]) {
    for gi in 0..NG {
        kernels::attn_scores_k_group_with(
            KernelMode::Fused,
            &c.packed_k[gi * c.rows_pk * DH..(gi + 1) * c.rows_pk * DH],
            G,
            DH,
            c.bits,
            &c.params_k[gi * DH..(gi + 1) * DH],
            q,
            &mut scores[gi * G..(gi + 1) * G],
        );
    }
    softmax_inplace(scores);
    out[..DH].fill(0.0);
    for gi in 0..NG {
        kernels::attn_weighted_v_group_with(
            KernelMode::Fused,
            &c.packed_v[gi * G * c.bpt..(gi + 1) * G * c.bpt],
            G,
            DH,
            G2,
            c.bits,
            &c.params_v[gi * G * c.dg..(gi + 1) * G * c.dg],
            &scores[gi * G..(gi + 1) * G],
            out,
        );
    }
}

/// Pre-fused path: wordpack unfold into a group-sized f32 scratch, then
/// the same dot8 / weighted_acc the fused kernels replicate in-register.
fn attn_unfold(
    c: &PackedCache,
    q: &[f32],
    scratch: &mut [f32],
    scores: &mut [f32],
    out: &mut [f32],
) {
    for gi in 0..NG {
        kernels::unfold_k_group_with(
            KernelMode::Wordpack,
            &c.packed_k[gi * c.rows_pk * DH..(gi + 1) * c.rows_pk * DH],
            G,
            DH,
            c.bits,
            &c.params_k[gi * DH..(gi + 1) * DH],
            scratch,
        );
        for t in 0..G {
            scores[gi * G + t] = kernels::dot8(q, &scratch[t * DH..(t + 1) * DH]);
        }
    }
    softmax_inplace(scores);
    out[..DH].fill(0.0);
    for gi in 0..NG {
        kernels::unfold_v_group_with(
            KernelMode::Wordpack,
            &c.packed_v[gi * G * c.bpt..(gi + 1) * G * c.bpt],
            G,
            DH,
            G2,
            c.bits,
            &c.params_v[gi * G * c.dg..(gi + 1) * G * c.dg],
            scratch,
        );
        kernels::weighted_acc(&scores[gi * G..(gi + 1) * G], scratch, G, DH, out);
    }
}

fn main() {
    let reps = bench::samples(200);
    let warm = bench::warmup(10);
    let mut rng = SplitMix::new(0xF05E);
    let k: Vec<f32> = rng.normal_f32_vec(N * DH);
    let v: Vec<f32> = rng.normal_f32_vec(N * DH);
    let q: Vec<f32> = rng.normal_f32_vec(DH);
    // fp32-equivalent attention traffic: K read + V read per decode step
    let bytes = N * DH * 4 * 2;

    bench::note(
        "bench_fused",
        &format!(
            "\nFused dequant-attention decode — 1 query over N={N} tokens, \
             Dh={DH}, G={G}, g2={G2}, {reps} samples"
        ),
    );
    let mut t = Table::new(
        "decode attention (per query row)",
        &["bits", "impl", "p50", "throughput", "vs unfold"],
    );
    let mut report = JsonReport::at_root("BENCH_kernels.json");
    let mut floors: Vec<(u8, f64)> = Vec::new();

    let mut scratch = vec![0f32; G * DH];
    let mut scores = vec![0f32; N];
    let mut scores_ref = vec![0f32; N];
    let mut out = vec![0f32; DH];
    let mut out_ref = vec![0f32; DH];

    for bits in [1u8, 2, 4, 8] {
        let c = fold_cache(bits, &k, &v);

        // the fused kernels must be a pure layout fusion: bit-identical
        // scores and output, not merely close
        attn_fused(&c, &q, &mut scores, &mut out);
        attn_unfold(&c, &q, &mut scratch, &mut scores_ref, &mut out_ref);
        assert!(
            scores.iter().zip(&scores_ref).all(|(a, b)| a.to_bits() == b.to_bits()),
            "fused scores diverge from unfold-then-dot8 at {bits}b"
        );
        assert!(
            out.iter().zip(&out_ref).all(|(a, b)| a.to_bits() == b.to_bits()),
            "fused weighted V diverges from unfold-then-weighted_acc at {bits}b"
        );

        let tm = time_fn(warm, reps, || {
            attn_unfold(&c, &q, &mut scratch, &mut scores, &mut out);
            std::hint::black_box(&out);
        });
        let unfold_mean = tm.mean();
        t.row(vec![
            bits.to_string(),
            "wordpack+dot8".into(),
            fmt_duration(tm.p50()),
            fmt_throughput(bytes as f64 / tm.mean()),
            String::new(),
        ]);
        report.add(
            &format!("unfold_attn_{bits}bit"),
            &tm,
            bytes,
            cfg(bits, "wordpack+dot8"),
        );

        let tm = time_fn(warm, reps, || {
            attn_fused(&c, &q, &mut scores, &mut out);
            std::hint::black_box(&out);
        });
        let ratio = unfold_mean / tm.mean();
        t.row(vec![
            bits.to_string(),
            "fused".into(),
            fmt_duration(tm.p50()),
            fmt_throughput(bytes as f64 / tm.mean()),
            format!("{ratio:.2}x"),
        ]);
        let mut config = cfg(bits, "fused");
        if let Value::Obj(o) = &mut config {
            o.insert("ratio_vs_unfold".into(), Value::num(ratio));
        }
        report.add(&format!("fused_attn_{bits}bit"), &tm, bytes, config);
        if bits <= 2 {
            floors.push((bits, ratio));
        }
    }

    // fused floor: >= 1.5x over unfold-then-matmul at the 1–2 bit tiers.
    // Smoke runs take too few samples for a stable ratio.
    if !bench::smoke() {
        for (bits, ratio) in &floors {
            assert!(
                *ratio >= 1.5,
                "fused_attn_{bits}bit: ratio {ratio:.2} below the 1.5x floor vs unfold"
            );
        }
    }

    t.emit("bench_fused");
    report.write().expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json (fused_attn_*/unfold_attn_* records)");
}
