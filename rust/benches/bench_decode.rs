//! Steady-state decode host-overhead bench: the incremental assembly path
//! (persistent staged literals + tail patches + step arena) vs the naive
//! `ASYMKV_NAIVE=1` baseline (full per-step gather + full rebuild).
//!
//! Two parts:
//!
//! 1. **Pure-Rust host model** (runs everywhere, emits the CI-asserted
//!    records): drives single-token decode steps against real
//!    `LayerCache`s at a serving-shaped geometry and measures, per step,
//!    the host assembly (gather/patch) plus an upload *proxy* — a memcpy
//!    of every buffer a literal build would copy (clean steps re-upload
//!    only residual + masks; fold steps additionally re-upload the packed
//!    set; naive steps rebuild and re-upload everything). A counting
//!    global allocator proves the steady-state gather path performs zero
//!    heap allocations.
//! 2. **End-to-end engine decode** (needs AOT artifacts; skips cleanly in
//!    smoke mode without them): times `Engine::decode` in both modes via
//!    `Engine::set_naive` and records real per-step literal-build bytes
//!    from `EngineStats`.
//!
//! Records: `decode_host_naive`, `decode_host_incremental`,
//! `decode_host_incremental_clean`, `decode_e2e_{incremental,naive}`
//! (see docs/BENCH.md). CI's bench-smoke job asserts
//! `decode_host_incremental.config.ratio_vs_naive >= 3` and
//! `gather_allocs_steady == 0`.

use asymkv::engine::gather::{
    gather_layer_args, GatherGeo, StagedLayer, StepArena,
};
use asymkv::kvcache::{CacheGeometry, SeqCache};
use asymkv::quant::QuantPolicy;
use asymkv::util::bench::{
    self, alloc_events, fmt_duration, time_fn, CountingAlloc, JsonReport, Table,
};
use asymkv::util::json::Value;
use asymkv::util::rng::SplitMix;

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

const H: usize = 8;
const T: usize = 4096;
const DH: usize = 64;
const G: usize = 32;
const R: usize = 64;
const LAYERS: usize = 4;
const FILL: usize = 2048;

/// Preallocated destination buffers standing in for literal construction:
/// a literal build is a copy of the full host buffer, so the proxy copies
/// exactly what the engine would upload. Returns bytes copied.
#[derive(Default)]
struct Upload {
    u8s: Vec<u8>,
    f32s: Vec<f32>,
}

impl Upload {
    fn fit(&mut self, u8_cap: usize, f32_cap: usize) {
        self.u8s.resize(u8_cap, 0);
        self.f32s.resize(f32_cap, 0.0);
    }
    fn copy_u8(&mut self, src: &[u8]) -> usize {
        self.u8s[..src.len()].copy_from_slice(src);
        src.len()
    }
    fn copy_f32(&mut self, src: &[f32]) -> usize {
        self.f32s[..src.len()].copy_from_slice(src);
        src.len() * 4
    }
}

fn fill_seq(policy: &QuantPolicy, rng: &mut SplitMix) -> SeqCache {
    let geo = CacheGeometry {
        n_heads: H, max_ctx: T, d_head: DH, group: G, residual: R,
    };
    let mut s = SeqCache::new(geo, policy);
    let hd = H * DH;
    for layer in &mut s.layers {
        let ks = rng.normal_f32_vec(FILL * hd);
        let vs = rng.normal_f32_vec(FILL * hd);
        layer.append_tokens(FILL, &ks, &vs);
        // drain the ring so the clean-step window below fits without folds
        while layer.n_res() >= G {
            layer.fold_oldest_group();
        }
    }
    s
}

fn main() {
    let ggeo = GatherGeo {
        b_art: 1, n_heads: H, max_ctx: T, d_head: DH, group: G, residual: R,
    };
    let policy = QuantPolicy::kivi(LAYERS, 1); // 1-bit K and V (KIVI-style)
    let hd = H * DH;
    let mut rng = SplitMix::new(0xDECD);
    let mut report = JsonReport::at_root("BENCH_kernels.json");
    let mut table = Table::new(
        "decode-step host overhead (gather + literal-build proxy, per step)",
        &["path", "per-step p50", "bytes/step", "note"],
    );
    bench::note(
        "bench_decode",
        &format!(
            "\nIncremental vs naive decode host overhead — B=1, H={H}, T={T}, \
             Dh={DH}, G={G}, R={R}, L={LAYERS}, policy {}, {FILL} cached tokens",
            policy.name
        ),
    );

    // ---- naive baseline: full gather + full upload every step ----------
    let mut naive_seq = fill_seq(&policy, &mut rng);
    let mut up = Upload::default();
    up.fit(
        H * T / 8 * DH + H * T * DH / 8 + 16,
        2 * (H * (T / G) * DH + H * T * (DH / G.min(DH))) + 2 * H * R * DH + T + R,
    );
    let naive_window = bench::samples(10);
    let naive_warm = bench::warmup(2);
    let mut naive_bytes = 0usize;
    let mut naive_steps = 0usize;
    let tm_naive = time_fn(naive_warm, naive_window, || {
        for _ in 0..G {
            let k = rng.normal_f32_vec(hd);
            for layer in &mut naive_seq.layers {
                layer.append_token(&k, &k);
            }
            let mut step_bytes = 0usize;
            for li in 0..LAYERS {
                let seqs = [&naive_seq];
                let args = gather_layer_args(&ggeo, &seqs, li);
                step_bytes += up.copy_u8(&args.k_main)
                    + up.copy_u8(&args.v_main)
                    + up.copy_f32(&args.k_main_f32)
                    + up.copy_f32(&args.v_main_f32)
                    + up.copy_f32(&args.k_scales)
                    + up.copy_f32(&args.k_zeros)
                    + up.copy_f32(&args.v_scales)
                    + up.copy_f32(&args.v_zeros)
                    + up.copy_f32(&args.k_res)
                    + up.copy_f32(&args.v_res)
                    + up.copy_f32(&args.mask_q)
                    + up.copy_f32(&args.mask_r);
                std::hint::black_box(&args);
            }
            naive_bytes += step_bytes;
            naive_steps += 1;
        }
    });
    let naive_step_s = tm_naive.mean() / G as f64;
    let naive_bps = naive_bytes / naive_steps.max(1);

    // ---- incremental: staged sync + tail patches + arena ----------------
    let mut seq = fill_seq(&policy, &mut rng);
    let mut staged: Vec<StagedLayer> =
        (0..LAYERS).map(|_| StagedLayer::new()).collect();
    let mut arena = StepArena::default();
    let ids = [1u64];
    // build the staging once (outside all measurements)
    {
        let seqs = [&seq];
        arena.begin_step(&ggeo, 1, 8);
        for (li, st) in staged.iter_mut().enumerate() {
            st.sync(&ggeo, &ids, &seqs, li);
        }
    }

    // one incremental step: arena + masks + per-layer sync + upload proxy
    // (clean step: residual + masks only; fold step: plus the packed set —
    // exactly what the engine rebuilds as literals). Returns (bytes, allocs).
    let mut step_incremental = |seq: &mut SeqCache,
                                staged: &mut [StagedLayer],
                                arena: &mut StepArena,
                                up: &mut Upload|
     -> (usize, u64) {
        let k = rng.normal_f32_vec(hd);
        for layer in &mut seq.layers {
            layer.append_token(&k, &k);
        }
        let a0 = alloc_events();
        let mut bytes = 0usize;
        let seqs = [&*seq];
        arena.begin_step(&ggeo, 1, 8);
        let lc0_q = seqs[0].layers[0].n_q;
        let lc0_res = seqs[0].layers[0].n_res();
        for i in 0..lc0_q {
            arena.mask_q[i] = 0.0;
        }
        for i in 0..lc0_res {
            arena.mask_r[i] = 0.0;
        }
        for (li, st) in staged.iter_mut().enumerate() {
            let rep = st.sync(&ggeo, &ids, &seqs, li);
            // upload proxy: what the engine rebuilds as literals
            bytes += up.copy_f32(&st.k_res) + up.copy_f32(&st.v_res);
            if !rep.packed_clean {
                bytes += up.copy_u8(&st.k_main)
                    + up.copy_u8(&st.v_main)
                    + up.copy_f32(&st.k_main_f32)
                    + up.copy_f32(&st.v_main_f32)
                    + up.copy_f32(&st.k_scales)
                    + up.copy_f32(&st.k_zeros)
                    + up.copy_f32(&st.v_scales)
                    + up.copy_f32(&st.v_zeros);
            }
        }
        bytes += up.copy_f32(&arena.mask_q) + up.copy_f32(&arena.mask_r);
        let allocs = alloc_events() - a0;
        (bytes, allocs)
    };

    // (a) pure clean steps: the ring was drained below one group, so a
    // window of at most R-G steps can never fold
    let clean_samples = bench::samples(26);
    let clean_warm = bench::warmup(3);
    assert!(clean_warm + clean_samples <= R - G, "clean window must not fold");
    let mut clean_bytes = 0usize;
    let mut clean_steps = 0usize;
    let mut gather_allocs = 0u64;
    let tm_clean = time_fn(clean_warm, clean_samples, || {
        let (b, a) = step_incremental(&mut seq, &mut staged, &mut arena, &mut up);
        clean_bytes += b;
        gather_allocs += a;
        clean_steps += 1;
    });

    // (b) blended steady state: windows of G steps, each naturally
    // containing its fold/tail-patch step
    let win_samples = bench::samples(10);
    let win_warm = bench::warmup(2);
    let mut win_bytes = 0usize;
    let mut win_steps = 0usize;
    let tm_win = time_fn(win_warm, win_samples, || {
        for _ in 0..G {
            let (b, _) = step_incremental(&mut seq, &mut staged, &mut arena, &mut up);
            win_bytes += b;
            win_steps += 1;
        }
    });
    let incr_step_s = tm_win.mean() / G as f64;
    let incr_bps = win_bytes / win_steps.max(1);
    let ratio = naive_step_s / incr_step_s.max(1e-12);
    let bytes_ratio = naive_bps as f64 / incr_bps.max(1) as f64;

    table.row(vec![
        "naive (ASYMKV_NAIVE=1)".into(),
        fmt_duration(naive_step_s),
        format!("{naive_bps}"),
        "full gather + full upload".into(),
    ]);
    table.row(vec![
        "incremental (blended)".into(),
        fmt_duration(incr_step_s),
        format!("{incr_bps}"),
        format!("{ratio:.1}x less host time, {bytes_ratio:.1}x fewer bytes"),
    ]);
    table.row(vec![
        "incremental (clean step)".into(),
        fmt_duration(tm_clean.mean()),
        format!("{}", clean_bytes / clean_steps.max(1)),
        format!("{gather_allocs} gather-path allocs"),
    ]);
    assert_eq!(gather_allocs, 0, "steady-state gather path must not allocate");
    assert!(
        ratio >= 3.0,
        "incremental decode host overhead must be >= 3x below naive, got {ratio:.2}x"
    );

    let cfg = |extra: Vec<(&str, Value)>| -> Value {
        let mut v = vec![
            ("b", Value::num(1.0)),
            ("heads", Value::num(H as f64)),
            ("max_ctx", Value::num(T as f64)),
            ("dh", Value::num(DH as f64)),
            ("group", Value::num(G as f64)),
            ("residual", Value::num(R as f64)),
            ("layers", Value::num(LAYERS as f64)),
            ("policy", Value::str_of(policy.name.clone())),
            ("note", Value::str_of(
                "per-step host assembly + literal-build (upload) proxy; \
                 timing samples are G-step windows divided by G",
            )),
        ];
        v.extend(extra);
        Value::obj(v)
    };
    // per-step timings: synthesize per-step sample sets from the windows
    let per_step = |t: &bench::Timing| bench::Timing {
        samples: t.samples.iter().map(|s| s / G as f64).collect(),
    };
    report.add(
        "decode_host_naive",
        &per_step(&tm_naive),
        naive_bps,
        cfg(vec![("bytes_per_step", Value::num(naive_bps as f64))]),
    );
    report.add(
        "decode_host_incremental",
        &per_step(&tm_win),
        incr_bps,
        cfg(vec![
            ("ratio_vs_naive", Value::num(ratio)),
            ("bytes_ratio_vs_naive", Value::num(bytes_ratio)),
            ("bytes_per_step", Value::num(incr_bps as f64)),
            ("bytes_per_step_naive", Value::num(naive_bps as f64)),
            ("gather_allocs_steady", Value::num(gather_allocs as f64)),
        ]),
    );
    report.add(
        "decode_host_incremental_clean",
        &tm_clean,
        clean_bytes / clean_steps.max(1),
        cfg(vec![(
            "bytes_per_step",
            Value::num((clean_bytes / clean_steps.max(1)) as f64),
        )]),
    );

    // ---- end-to-end engine decode (artifact-gated) ----------------------
    e2e(&mut report, &mut table);

    table.emit("bench_decode");
    report.write().expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json (decode_host_*/decode_e2e_* records)");
}

/// Real `Engine::decode` A/B via `set_naive` when artifacts are present.
fn e2e(report: &mut JsonReport, table: &mut Table) {
    use asymkv::engine::Engine;
    use asymkv::model::ByteTokenizer;
    use asymkv::runtime::Runtime;
    use std::sync::Arc;

    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("[bench_decode] artifacts unavailable ({e}); skipping e2e A/B");
            return;
        }
    };
    let engine = match Engine::new(rt, 1 << 30) {
        Ok(e) => e,
        Err(e) => {
            println!("[bench_decode] engine unavailable ({e}); skipping e2e A/B");
            return;
        }
    };
    let m = engine.manifest();
    let n = m.n_layers;
    let policy = QuantPolicy::kivi(n, 1);
    let tok = ByteTokenizer;
    let mut rng = SplitMix::new(42);
    let doc = asymkv::workload::gen_document(&mut rng, 100);
    let samples = bench::samples(24);
    let warm = bench::warmup(3);

    let mut run = |naive: bool, name: &str| -> Option<()> {
        engine.set_naive(naive);
        let id = engine.create_seq(&policy).ok()?;
        engine.prefill(&[id], &[tok.encode(&doc)]).ok()?;
        let s0 = engine.stats();
        let tm = time_fn(warm, samples, || {
            engine.decode(&[id], &[65]).unwrap();
        });
        let s1 = engine.stats();
        let steps = (s1.decode_steps - s0.decode_steps).max(1);
        let bytes_per_step =
            (s1.literal_bytes_built - s0.literal_bytes_built) / steps;
        engine.free_seq(id).ok()?;
        table.row(vec![
            format!("e2e decode ({name})"),
            fmt_duration(tm.p50()),
            format!("{bytes_per_step}"),
            format!(
                "gather {:.1}ms build {:.1}ms exec {:.1}ms over run",
                (s1.gather_s - s0.gather_s) * 1e3,
                (s1.literal_build_s - s0.literal_build_s) * 1e3,
                (s1.exec_s - s0.exec_s) * 1e3
            ),
        ]);
        report.add(
            &format!("decode_e2e_{name}"),
            &tm,
            bytes_per_step as usize,
            Value::obj(vec![
                ("model", Value::str_of(m.name.clone())),
                ("policy", Value::str_of(policy.name.clone())),
                ("bytes_built_per_step", Value::num(bytes_per_step as f64)),
                ("naive", Value::Bool(naive)),
            ]),
        );
        Some(())
    };
    let _ = run(false, "incremental");
    let _ = run(true, "naive");
    engine.set_naive(false);
}
