//! Fold/unfold hot-path bench: per-channel K and per-token V group
//! quantize+pack (and the inverse), scalar vs wordpack vs simd, plus the
//! batched `append_tokens` prefill path vs per-token appends. Pure-Rust
//! (no artifacts), runs everywhere. Emits the `fold_*`, `unfold_*` and
//! `append_*` records of `BENCH_kernels.json` — the kernel-tier speedup
//! trajectory the CI bench-smoke job publishes. In full (non-smoke) runs
//! the simd V-path must clear 2x over wordpack at 1–2 bit; the committed
//! JSON carries `ratio_vs_wordpack` so CI can re-assert the floor without
//! re-measuring.

use asymkv::kvcache::{CacheGeometry, LayerCache};
use asymkv::quant::kernels::{self, GroupParams, KernelMode};
use asymkv::util::bench::{self, fmt_duration, fmt_throughput, time_fn, JsonReport, Table};
use asymkv::util::json::Value;
use asymkv::util::rng::SplitMix;

const MODES: [(KernelMode, &str); 3] = [
    (KernelMode::Scalar, "scalar"),
    (KernelMode::Wordpack, "wordpack"),
    (KernelMode::Simd, "simd"),
];

// One iteration folds/unfolds HEADS groups of [G, DH] — a full layer's
// fold work for one group boundary at an 8-head model.
const G: usize = 32;
const DH: usize = 128;
const G2: usize = 32;
const HEADS: usize = 8;

fn cfg(bits: u8, imp: &str) -> Value {
    Value::obj(vec![
        ("bits", Value::num(bits as f64)),
        ("impl", Value::str_of(imp)),
        ("g", Value::num(G as f64)),
        ("dh", Value::num(DH as f64)),
        ("g2", Value::num(G2 as f64)),
        ("heads", Value::num(HEADS as f64)),
    ])
}

fn main() {
    let reps = bench::samples(300);
    let warm = bench::warmup(20);
    let mut rng = SplitMix::new(0xF07D);
    let kg: Vec<f32> = rng.normal_f32_vec(HEADS * G * DH);
    let bytes = HEADS * G * DH * 4; // f32 input traffic per iteration

    bench::note(
        "bench_fold",
        &format!(
            "\nFold/unfold kernels — {HEADS} heads × [G={G}, Dh={DH}], g2={G2}, {reps} samples"
        ),
    );
    let mut t = Table::new(
        "fold / unfold (per call over all heads)",
        &["op", "bits", "impl", "p50", "throughput", "speedup"],
    );
    let mut report = JsonReport::at_root("BENCH_kernels.json");
    // (record name, simd-over-wordpack ratio) for the V-path floor check
    let mut v_floors: Vec<(String, f64)> = Vec::new();

    for bits in [1u8, 2, 4, 8] {
        let rows_pk = kernels::packed_len(G, bits);
        let bpt = kernels::packed_len(DH, bits);
        let dg = DH / G2;
        let mut packed_k = vec![0u8; HEADS * rows_pk * DH];
        let mut params_k = vec![GroupParams { scale: 0.0, zero: 0.0 }; HEADS * DH];
        let mut packed_v = vec![0u8; HEADS * G * bpt];
        let mut params_v = vec![GroupParams { scale: 0.0, zero: 0.0 }; HEADS * G * dg];
        let mut out = vec![0f32; HEADS * G * DH];

        // fold_k, unfold_k, fold_v, unfold_v, fold_unfold_k, fold_unfold_v
        // [op][0] = scalar mean, [op][1] = wordpack mean
        let mut base_means = [[0f64; 2]; 6];
        for (mode, name) in MODES {
            // fold K
            let tm = time_fn(warm, reps, || {
                for h in 0..HEADS {
                    kernels::fold_k_group_with(
                        mode,
                        &kg[h * G * DH..(h + 1) * G * DH],
                        G,
                        DH,
                        bits,
                        &mut packed_k[h * rows_pk * DH..(h + 1) * rows_pk * DH],
                        &mut params_k[h * DH..(h + 1) * DH],
                    );
                }
                std::hint::black_box(&packed_k);
            });
            emit(&mut t, &mut report, "fold_k", bits, name, &tm, bytes, &mut base_means[0]);

            // unfold K
            let tm = time_fn(warm, reps, || {
                for h in 0..HEADS {
                    kernels::unfold_k_group_with(
                        mode,
                        &packed_k[h * rows_pk * DH..(h + 1) * rows_pk * DH],
                        G,
                        DH,
                        bits,
                        &params_k[h * DH..(h + 1) * DH],
                        &mut out[h * G * DH..(h + 1) * G * DH],
                    );
                }
                std::hint::black_box(&out);
            });
            emit(&mut t, &mut report, "unfold_k", bits, name, &tm, bytes, &mut base_means[1]);

            // fold V
            let tm = time_fn(warm, reps, || {
                for h in 0..HEADS {
                    kernels::fold_v_group_with(
                        mode,
                        &kg[h * G * DH..(h + 1) * G * DH],
                        G,
                        DH,
                        G2,
                        bits,
                        &mut packed_v[h * G * bpt..(h + 1) * G * bpt],
                        &mut params_v[h * G * dg..(h + 1) * G * dg],
                    );
                }
                std::hint::black_box(&packed_v);
            });
            if let Some(r) =
                emit(&mut t, &mut report, "fold_v", bits, name, &tm, bytes, &mut base_means[2])
            {
                if bits <= 2 {
                    v_floors.push((format!("fold_v_{bits}bit_simd"), r));
                }
            }

            // unfold V
            let tm = time_fn(warm, reps, || {
                for h in 0..HEADS {
                    kernels::unfold_v_group_with(
                        mode,
                        &packed_v[h * G * bpt..(h + 1) * G * bpt],
                        G,
                        DH,
                        G2,
                        bits,
                        &params_v[h * G * dg..(h + 1) * G * dg],
                        &mut out[h * G * DH..(h + 1) * G * DH],
                    );
                }
                std::hint::black_box(&out);
            });
            if let Some(r) =
                emit(&mut t, &mut report, "unfold_v", bits, name, &tm, bytes, &mut base_means[3])
            {
                if bits <= 2 {
                    v_floors.push((format!("unfold_v_{bits}bit_simd"), r));
                }
            }

            // the fold/unfold PATH: quantize+pack then unpack+dequantize —
            // the roundtrip every cached token pays, and the headline
            // scalar-vs-wordpack comparison of the perf trajectory
            let tm = time_fn(warm, reps, || {
                for h in 0..HEADS {
                    kernels::fold_k_group_with(
                        mode,
                        &kg[h * G * DH..(h + 1) * G * DH],
                        G,
                        DH,
                        bits,
                        &mut packed_k[h * rows_pk * DH..(h + 1) * rows_pk * DH],
                        &mut params_k[h * DH..(h + 1) * DH],
                    );
                    kernels::unfold_k_group_with(
                        mode,
                        &packed_k[h * rows_pk * DH..(h + 1) * rows_pk * DH],
                        G,
                        DH,
                        bits,
                        &params_k[h * DH..(h + 1) * DH],
                        &mut out[h * G * DH..(h + 1) * G * DH],
                    );
                }
                std::hint::black_box(&out);
            });
            emit(&mut t, &mut report, "fold_unfold_k", bits, name, &tm, bytes * 2,
                 &mut base_means[4]);

            let tm = time_fn(warm, reps, || {
                for h in 0..HEADS {
                    kernels::fold_v_group_with(
                        mode,
                        &kg[h * G * DH..(h + 1) * G * DH],
                        G,
                        DH,
                        G2,
                        bits,
                        &mut packed_v[h * G * bpt..(h + 1) * G * bpt],
                        &mut params_v[h * G * dg..(h + 1) * G * dg],
                    );
                    kernels::unfold_v_group_with(
                        mode,
                        &packed_v[h * G * bpt..(h + 1) * G * bpt],
                        G,
                        DH,
                        G2,
                        bits,
                        &params_v[h * G * dg..(h + 1) * G * dg],
                        &mut out[h * G * DH..(h + 1) * G * DH],
                    );
                }
                std::hint::black_box(&out);
            });
            emit(&mut t, &mut report, "fold_unfold_v", bits, name, &tm, bytes * 2,
                 &mut base_means[5]);
        }
    }

    // simd V-path floor: >= 2x over wordpack at the 1–2 bit tiers the
    // paper's flagship configs live at. Smoke runs take too few samples
    // for a stable ratio, so only full runs enforce it.
    if !bench::smoke() {
        for (name, ratio) in &v_floors {
            assert!(
                *ratio >= 2.0,
                "{name}: simd-over-wordpack ratio {ratio:.2} below the 2x floor"
            );
        }
    }

    // batched vs per-token append (2-bit K / 2-bit V, active kernel mode)
    let geo = CacheGeometry { n_heads: HEADS, max_ctx: 512, d_head: DH, group: G, residual: 64 };
    let hd = HEADS * DH;
    let count = 256;
    let ks: Vec<f32> = rng.normal_f32_vec(count * hd);
    let vs: Vec<f32> = rng.normal_f32_vec(count * hd);
    let app_bytes = count * hd * 4 * 2;

    let tm = time_fn(bench::warmup(3), bench::samples(50), || {
        let mut c = LayerCache::new(geo, 2, 2);
        for t in 0..count {
            c.append_token(&ks[t * hd..(t + 1) * hd], &vs[t * hd..(t + 1) * hd]);
        }
        std::hint::black_box(c.n_tokens());
    });
    t.row(vec![
        "append per-token".into(),
        "2".into(),
        "dispatch".into(),
        fmt_duration(tm.p50()),
        fmt_throughput(app_bytes as f64 / tm.mean()),
        String::new(),
    ]);
    report.add(
        &format!("append_per_token_{count}toks"),
        &tm,
        app_bytes,
        cfg(2, "dispatch"),
    );
    let per_token_mean = tm.mean();

    let tm = time_fn(bench::warmup(3), bench::samples(50), || {
        let mut c = LayerCache::new(geo, 2, 2);
        c.append_tokens(count, &ks, &vs);
        std::hint::black_box(c.n_tokens());
    });
    t.row(vec![
        "append batched".into(),
        "2".into(),
        "dispatch".into(),
        fmt_duration(tm.p50()),
        fmt_throughput(app_bytes as f64 / tm.mean()),
        format!("{:.2}x", per_token_mean / tm.mean()),
    ]);
    report.add(
        &format!("append_batched_{count}toks"),
        &tm,
        app_bytes,
        cfg(2, "dispatch"),
    );

    t.emit("bench_fold");
    report.write().expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json (fold_*/unfold_*/append_* records)");
}

/// Table row + JSON record; stashes the scalar/wordpack means so later
/// tiers of the same op can print and record their speedups. Returns the
/// simd-over-wordpack ratio (the CI floor metric) on simd rows.
#[allow(clippy::too_many_arguments)]
fn emit(
    t: &mut Table,
    report: &mut JsonReport,
    op: &str,
    bits: u8,
    imp: &str,
    tm: &asymkv::util::bench::Timing,
    bytes: usize,
    means: &mut [f64; 2],
) -> Option<f64> {
    let speedup = if imp == "scalar" {
        means[0] = tm.mean();
        String::new()
    } else {
        if imp == "wordpack" {
            means[1] = tm.mean();
        }
        format!("{:.2}x", means[0] / tm.mean())
    };
    t.row(vec![
        op.into(),
        bits.to_string(),
        imp.into(),
        fmt_duration(tm.p50()),
        fmt_throughput(bytes as f64 / tm.mean()),
        speedup,
    ]);
    let mut config = cfg(bits, imp);
    let ratio_vs_wordpack = (imp == "simd").then(|| means[1] / tm.mean());
    if let asymkv::util::json::Value::Obj(o) = &mut config {
        if imp != "scalar" {
            o.insert("speedup_vs_scalar".into(), Value::num(means[0] / tm.mean()));
        }
        if let Some(r) = ratio_vs_wordpack {
            o.insert("ratio_vs_wordpack".into(), Value::num(r));
        }
    }
    report.add(&format!("{op}_{bits}bit_{imp}"), tm, bytes, config);
    ratio_vs_wordpack
}
