//! Session hibernation bench: the PR's restore-vs-re-prefill argument
//! made measurable. When the idle sweep destroys a session, the
//! conversation's next turn pays a full prefill of the retained history
//! through the quantized fold kernels. Hibernation spills the frozen
//! 1-bit image to disk instead; the next turn's cost is read + decode +
//! pool re-admission. At AsymKV's 1-bit flagship the image is tiny, so
//! restore must beat re-prefill by a wide margin — the CI floor is 3x —
//! while producing the EXACT bytes the donor held (asserted here and
//! proved decode-bit-identical by `tests/hibernate_equivalence.rs`).
//! Pure-Rust (no artifacts), runs everywhere. Emits the `hibernate_*`
//! records of `BENCH_kernels.json`.

use asymkv::kvcache::{
    CacheGeometry, CachePool, HibernateConfig, HibernateStore,
};
use asymkv::quant::QuantPolicy;
use asymkv::util::bench::{self, fmt_duration, time_fn, JsonReport, Table};
use asymkv::util::json::Value;
use asymkv::util::rng::SplitMix;

const GEO: CacheGeometry = CacheGeometry {
    n_heads: 8,
    max_ctx: 4096,
    d_head: 64,
    group: 32,
    residual: 64,
};
const LAYERS: usize = 4;

fn policy() -> QuantPolicy {
    QuantPolicy::kivi(LAYERS, 1) // the 1-bit flagship
}

/// Append `count` synthetic tokens through the real quantized fold path.
fn grow(pool: &CachePool, id: u64, count: usize, seed: u64) {
    let hd = GEO.n_heads * GEO.d_head;
    let mut rng = SplitMix::new(seed);
    pool.with_seq(id, |s| {
        for _ in 0..count {
            for layer in &mut s.layers {
                let k = rng.normal_f32_vec(hd);
                let v = rng.normal_f32_vec(hd);
                layer.append_token(&k, &v);
            }
            s.pos += 1;
        }
    })
    .unwrap();
}

fn main() {
    let p = policy();
    // smoke keeps CI fast; the full run measures a realistic conversation
    let tokens: usize = if bench::smoke() { 256 } else { 1024 };
    let reps = bench::samples(30);
    let warm = bench::warmup(3);

    let dir = std::env::temp_dir()
        .join(format!("asymkv-bench-hib-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = HibernateStore::new(HibernateConfig {
        dir: dir.clone(),
        budget_bytes: 1 << 30,
    })
    .expect("spill dir");

    // donor session: `tokens` of history resident at 1-bit
    let pool = CachePool::new(GEO, usize::MAX);
    let donor = pool.allocate(&p).unwrap();
    grow(&pool, donor, tokens, 0xD0);
    let frozen = pool
        .with_seq(donor, |s| asymkv::kvcache::SeqBase::freeze(s))
        .unwrap();
    let image_bytes = store.spill(1, &frozen, "1:1,1:1,1:1,1:1").unwrap();

    // ---- A: the eviction path — next turn re-prefills the history ----
    let tm_reprefill = time_fn(warm, reps, || {
        let id = pool.allocate(&p).unwrap();
        grow(&pool, id, tokens, 0xD0);
        pool.free(id).unwrap();
        std::hint::black_box(id);
    });

    // ---- B: the hibernation path — read + decode + re-admit ----
    let tm_restore = time_fn(warm, reps, || {
        let img = store.restore(1).expect("image resident");
        let id = pool.adopt(img.into_seq()).expect("budget is unbounded");
        pool.free(id).unwrap();
        std::hint::black_box(id);
    });

    // ---- spill cost (encode + temp-rename write), for the sweeper ----
    let tm_spill = time_fn(warm, reps, || {
        let n = store.spill(2, &frozen, "1:1,1:1,1:1,1:1").unwrap();
        std::hint::black_box(n);
    });
    store.discard(2);

    // restored bytes must equal the donor's exactly
    let img = store.restore(1).unwrap();
    let restored = img.into_seq();
    let bit_identical = pool
        .with_seq(donor, |d| {
            d.pos == restored.pos
                && d.layers.iter().zip(restored.layers.iter()).all(|(a, b)| {
                    a.dequant_k_full() == b.dequant_k_full()
                        && a.dequant_v_full() == b.dequant_v_full()
                })
        })
        .unwrap();
    assert!(bit_identical, "restore must reproduce the donor bytes");

    let ratio = tm_reprefill.p50() / tm_restore.p50();
    assert!(
        ratio >= 3.0,
        "restore must beat re-prefill >= 3x at 1-bit \
         (got {:.1}x: reprefill {} vs restore {})",
        ratio,
        fmt_duration(tm_reprefill.p50()),
        fmt_duration(tm_restore.p50()),
    );

    let mut t = Table::new(
        "session hibernation: next-turn readiness after the idle sweep",
        &["path", "p50", "p95", "vs re-prefill"],
    );
    t.row(vec![
        format!("re-prefill {tokens} tokens"),
        fmt_duration(tm_reprefill.p50()),
        fmt_duration(tm_reprefill.p95()),
        "1.0x".into(),
    ]);
    t.row(vec![
        format!("restore {image_bytes}B image"),
        fmt_duration(tm_restore.p50()),
        fmt_duration(tm_restore.p95()),
        format!("{ratio:.1}x"),
    ]);
    t.row(vec![
        "spill (freeze already held)".into(),
        fmt_duration(tm_spill.p50()),
        fmt_duration(tm_spill.p95()),
        "-".into(),
    ]);
    t.emit("bench_hibernate");

    let mut report = JsonReport::at_root("BENCH_kernels.json");
    report.add(
        "hibernate_restore_ttft",
        &tm_restore,
        image_bytes,
        Value::obj(vec![
            ("session_tokens", Value::num(tokens as f64)),
            ("layers", Value::num(LAYERS as f64)),
            ("policy", Value::str_of(p.name.clone())),
            ("image_bytes", Value::num(image_bytes as f64)),
            ("reprefill_p50_s", Value::num(tm_reprefill.p50())),
            ("restore_p50_s", Value::num(tm_restore.p50())),
            ("ratio_vs_reprefill", Value::num(ratio)),
            ("bit_identical", Value::Bool(bit_identical)),
        ]),
    );
    report.add(
        "hibernate_spill_roundtrip",
        &tm_spill,
        image_bytes,
        Value::obj(vec![
            ("session_tokens", Value::num(tokens as f64)),
            ("image_bytes", Value::num(image_bytes as f64)),
            ("spill_p50_s", Value::num(tm_spill.p50())),
            ("restore_p50_s", Value::num(tm_restore.p50())),
            ("policy", Value::str_of(p.name.clone())),
        ]),
    );
    report.write().expect("write BENCH_kernels.json");

    bench::note(
        "bench_hibernate",
        &format!(
            "\n{tokens}-token 1-bit session: {image_bytes}-byte image; \
             restore {} vs re-prefill {} ({ratio:.1}x); bytes verified \
             identical to the donor.",
            fmt_duration(tm_restore.p50()),
            fmt_duration(tm_reprefill.p50()),
        ),
    );
    let _ = std::fs::remove_dir_all(&dir);
    println!("wrote BENCH_kernels.json (hibernate_* records)");
}
