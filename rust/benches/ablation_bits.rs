//! Ablation: the high/low bit pair of the asymmetric scheme.
//!
//! The paper fixes (high, low) = (2, 1) but motivates "e.g. a 4-bit
//! strategy" for the high tier (§1/§4). This sweep varies the pair at a
//! fixed l_k = L/2, l_v = 0 and reports quality vs exact cache bytes —
//! validating that (2,1) sits at the knee the paper claims, plus the
//! sensitivity-ordered allocation extension at matched budgets.

use std::sync::Arc;

use asymkv::engine::Engine;
use asymkv::evals;
use asymkv::quant::QuantPolicy;
use asymkv::runtime::Runtime;
use asymkv::search;
use asymkv::util::bench::{note, Table};
use asymkv::workload::tasks;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();
    let n = m.n_layers;
    let suite = tasks::recall_suite(0xAB17, 20, 12);

    // full-context footprint: a FRESH sequence allocates ~nothing under
    // demand paging, so project the fully grown resident size instead
    let cache_kib = |p: &QuantPolicy| -> anyhow::Result<f64> {
        let b = engine
            .pool
            .estimate_bytes(p, m.max_ctx + m.residual - 1);
        Ok(b as f64 / 1024.0)
    };

    note("ablation_bits", &format!(
        "\nBit-pair ablation — model {}, l_k = {} of {n}, l_v = 0",
        m.name, n / 2));
    let float_acc =
        evals::recall_accuracy(&engine, &QuantPolicy::float32(n), &suite)?;
    let mut t = Table::new(
        "high:low ablation at fixed (l_k, l_v)",
        &["pair", "recall acc", "cache KiB", "frac of float"],
    );
    for (high, low) in [(2u8, 1u8), (4, 1), (4, 2), (2, 2), (1, 1)] {
        let p = QuantPolicy::asymkv(n, n / 2, 0, high, low);
        let acc = evals::recall_accuracy(&engine, &p, &suite)?;
        t.row(vec![
            format!("{high}:{low}"),
            format!("{acc:.3}"),
            format!("{:.1}", cache_kib(&p)?),
            format!("{:.2}", acc / float_acc.max(1e-9)),
        ]);
    }
    t.emit("ablation_bits");

    // --- sensitivity-ordered allocation vs the paper's prefix scheme ---
    note("ablation_bits",
         "\nExtension: per-slot sensitivity allocation vs prefix-l_k at \
          equal memory budgets (2·L+1 probe evaluations).");
    let probe_suite = tasks::recall_suite(0xAB18, 10, 12);
    let sens = search::measure_sensitivities(n, 2, 1, |p| {
        evals::recall_accuracy(&engine, p, &probe_suite).unwrap_or(0.0)
    });
    let mut t2 = Table::new(
        "sensitivity allocation vs prefix (same high-slot budget)",
        &["budget", "prefix policy", "prefix acc", "sens acc"],
    );
    for budget in [n / 2, n, n + n / 2] {
        let prefix = QuantPolicy::asymkv21(n, budget.min(n),
                                           budget.saturating_sub(n));
        let sens_p = search::sensitivity_allocate(&sens, n, budget, 2, 1);
        let pa = evals::recall_accuracy(&engine, &prefix, &suite)?;
        let sa = evals::recall_accuracy(&engine, &sens_p, &suite)?;
        t2.row(vec![
            budget.to_string(),
            prefix.name.clone(),
            format!("{pa:.3}"),
            format!("{sa:.3}"),
        ]);
    }
    t2.emit("ablation_bits");
    Ok(())
}
