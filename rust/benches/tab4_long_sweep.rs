//! Table 4 (appendix) — long-context mixed sweeps.
//!
//! Paper: fix one axis at the full layer budget and sweep the other —
//! AsymKV-32/l_v (keys all-high) vs AsymKV-l_k/32 (values all-high) on
//! LongBench; the keys-high family dominates throughout, and quality rises
//! with the swept budget.
//!
//! Here: AsymKV-8/l_v vs AsymKV-l_k/8 on needle recall at ctx 512.

use std::sync::Arc;

use asymkv::engine::Engine;
use asymkv::evals;
use asymkv::quant::QuantPolicy;
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::workload::tasks;

fn main() -> anyhow::Result<()> {
    let dir =
        std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small-long".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();
    let n = m.n_layers;

    let target = m.max_ctx * 2 / 3;
    let suite = tasks::needle_suite_bytes(0x7AB4, 20, target);

    note("tab4_long_sweep", &format!(
        "\nTable 4 reproduction — mixed sweeps at ctx {}, model {} \
         (paper: AsymKV-32/l and AsymKV-l/32 on LongBench)",
        m.max_ctx, m.name));

    let float_acc = evals::recall_accuracy(
        &engine, &QuantPolicy::float32(n), &suite)?;
    let kivi_acc = evals::recall_accuracy(
        &engine, &QuantPolicy::kivi(n, 2), &suite)?;

    let mut t = Table::new(
        "Tab.4: long-context mixed sweep (needle accuracy)",
        &["type", "acc ↑", "≥90% float?"],
    );
    t.row(vec!["float".into(), format!("{float_acc:.3}"), "".into()]);
    t.row(vec!["KIVI-2bit".into(), format!("{kivi_acc:.3}"), "".into()]);

    let ls = [0usize, 2, 4, 8];
    let mut keys_high = Vec::new();
    let mut vals_high = Vec::new();
    for &lv in &ls {
        let p = QuantPolicy::asymkv21(n, n, lv);
        let acc = evals::recall_accuracy(&engine, &p, &suite)?;
        keys_high.push(acc);
        t.row(vec![p.name.clone(), format!("{acc:.3}"),
                   if evals::meets_90pct(acc, float_acc) { "*" } else { "" }.into()]);
    }
    for &lk in &ls {
        let p = QuantPolicy::asymkv21(n, lk, n);
        let acc = evals::recall_accuracy(&engine, &p, &suite)?;
        vals_high.push(acc);
        t.row(vec![p.name.clone(), format!("{acc:.3}"),
                   if evals::meets_90pct(acc, float_acc) { "*" } else { "" }.into()]);
    }
    t.emit("tab4_long_sweep");

    // matched-memory comparison: AsymKV-8/l vs AsymKV-l/8 use the same bytes
    let dominated = keys_high
        .iter()
        .zip(&vals_high)
        .filter(|(k, v)| k >= v)
        .count();
    note("tab4_long_sweep", &format!(
        "\nPaper shape: the keys-high family dominates the values-high family \
         at matched memory in {dominated}/{} points.", ls.len()));
    Ok(())
}
