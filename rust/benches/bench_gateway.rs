//! Gateway fleet benchmarks — the measurements behind the HTTP/SSE
//! gateway's existence:
//!
//! 1. **Fan-out throughput** (`gateway_fanout_throughput`): N concurrent
//!    HTTP generates through a gateway over TWO replicas vs the same N
//!    through a gateway over ONE replica. Each mock replica serves
//!    generation strictly sequentially (one worker), so wall time is
//!    bounded below by (requests x service) / replicas — the ratio
//!    measures the router actually spreading load, not scheduler luck.
//!    CI asserts `config.ratio_2_vs_1 >= 1.6`.
//! 2. **Session affinity** (`gateway_affinity_hit_rate`): interleaved
//!    turns across many sessions pinned over two replicas. The mock
//!    replicas use replica-LOCAL session ids, so ANY mis-routed turn
//!    fails loudly — the hit rate is (affinity-routed turns) / (turns).
//!    CI asserts `config.hit_rate >= 0.9`.
//!
//! Pure loopback: real gateway + real v3 codec + mock replicas (fixed
//! per-token service time). Runs everywhere, no artifacts needed.

use std::sync::Arc;
use std::time::Duration;

use asymkv::gateway::testing::{http_json, MockReplica, MockReplicaConfig};
use asymkv::gateway::{Gateway, GatewayConfig};
use asymkv::util::bench::{self, fmt_duration, time_fn, JsonReport, Table};
use asymkv::util::json::Value;

/// Concurrent HTTP requests per measured fan-out run.
const N_REQ: usize = 16;
/// Tokens per generate; service per request = N_GEN x TOKEN_TIME.
const N_GEN: usize = 4;
const TOKEN_TIME: Duration = Duration::from_millis(2);
/// Sessions (and turns per measured round) for the affinity benchmark.
const N_SESSIONS: usize = 8;

struct Fleet {
    replicas: Vec<MockReplica>,
    gateway: Arc<Gateway>,
    addr: String,
}

fn boot_fleet(n: usize) -> Fleet {
    let replicas: Vec<MockReplica> = (0..n)
        .map(|_| {
            MockReplica::spawn(MockReplicaConfig {
                n_layers: 4,
                token_time: TOKEN_TIME,
            })
            .expect("spawn mock replica")
        })
        .collect();
    let addrs: Vec<String> =
        replicas.iter().map(|r| r.addr().to_string()).collect();
    let gateway = Arc::new(
        Gateway::bind("127.0.0.1:0", &addrs, GatewayConfig::default())
            .expect("bind gateway"),
    );
    let addr = gateway.local_addr();
    let serve = gateway.clone();
    std::thread::spawn(move || {
        let _ = serve.serve();
    });
    Fleet { replicas, gateway, addr }
}

fn gen_body(i: usize) -> Value {
    Value::obj(vec![
        ("prompt", Value::str_of(format!("req {i}"))),
        ("n_gen", Value::num(N_GEN as f64)),
    ])
}

/// N concurrent HTTP generates; every reply must be a 200.
fn run_fanout(addr: &str, n_req: usize) {
    let handles: Vec<_> = (0..n_req)
        .map(|i| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let (status, body) =
                    http_json(&addr, "POST", "/v1/generate", Some(&gen_body(i)))
                        .expect("http generate");
                assert_eq!(status, 200, "{body}");
            })
        })
        .collect();
    for h in handles {
        h.join().expect("fanout worker");
    }
}

/// One interleaved round: a turn on every session, in rotation.
fn run_turns(addr: &str, sessions: &[u64]) {
    for &id in sessions {
        let (status, body) = http_json(
            addr,
            "POST",
            &format!("/v1/sessions/{id}/turns"),
            Some(&Value::obj(vec![
                ("prompt", Value::str_of("turn")),
                ("n_gen", Value::num(1.0)),
            ])),
        )
        .expect("http turn");
        assert_eq!(status, 200, "mis-routed or refused turn: {body}");
    }
}

fn main() {
    let reps = bench::samples(8);
    let warm = bench::warmup(1);

    // ---- fan-out: 2 replicas vs 1 ------------------------------------
    let one = boot_fleet(1);
    let two = boot_fleet(2);
    let t_one = time_fn(warm, reps, || run_fanout(&one.addr, N_REQ));
    let t_two = time_fn(warm, reps, || run_fanout(&two.addr, N_REQ));
    // min-over-samples: a single sequential replica's wall time is
    // bounded below by N x service regardless of sample luck, while
    // stalls only inflate samples — min/min measures the architecture.
    let ratio = t_one.min() / t_two.min();
    let served: Vec<u64> = two.replicas.iter().map(|r| r.served()).collect();
    assert!(
        served.iter().all(|&s| s > 0),
        "the router never spread load: served per replica = {served:?}"
    );
    assert!(
        ratio >= 1.6,
        "2-replica fan-out must be >= 1.6x one replica \
         (got {ratio:.2}x: 1-replica min {:.4}s vs 2-replica min {:.4}s)",
        t_one.min(),
        t_two.min()
    );

    // ---- session affinity under interleaved traffic ------------------
    let mut sessions = Vec::new();
    for _ in 0..N_SESSIONS {
        let (status, body) = http_json(
            &two.addr,
            "POST",
            "/v1/sessions",
            Some(&Value::obj(vec![])),
        )
        .expect("open session");
        assert_eq!(status, 200, "{body}");
        sessions.push(body.get("session").as_i64().unwrap() as u64);
    }
    let (_, before) =
        http_json(&two.addr, "GET", "/v1/replicas", None).expect("replicas");
    let affinity_before =
        before.get("router").get("affinity_routes").as_f64().unwrap();
    let t_aff = time_fn(warm, reps, || run_turns(&two.addr, &sessions));
    let (_, after) =
        http_json(&two.addr, "GET", "/v1/replicas", None).expect("replicas");
    let affinity_after =
        after.get("router").get("affinity_routes").as_f64().unwrap();
    let turns = ((warm + reps) * N_SESSIONS) as f64;
    // every turn either routed to its pin (affinity_routes ticked and the
    // replica accepted the session id) or the 200-assert above fired
    let hit_rate = (affinity_after - affinity_before) / turns;
    assert!(
        hit_rate >= 0.9,
        "session affinity hit rate {hit_rate:.3} < 0.9 \
         ({affinity_before} -> {affinity_after} over {turns} turns)"
    );

    // ---- report -------------------------------------------------------
    let mut t = Table::new(
        "gateway fleet: fan-out throughput and session affinity",
        &["measure", "wall (p50)", "detail"],
    );
    t.row(vec![
        format!("{N_REQ} generates, 1 replica"),
        fmt_duration(t_one.p50()),
        format!("{:.0} req/s", N_REQ as f64 / t_one.p50()),
    ]);
    t.row(vec![
        format!("{N_REQ} generates, 2 replicas"),
        fmt_duration(t_two.p50()),
        format!("{ratio:.2}x one replica"),
    ]);
    t.row(vec![
        format!("{N_SESSIONS} interleaved turns"),
        fmt_duration(t_aff.p50()),
        format!("affinity hit rate {hit_rate:.3}"),
    ]);
    t.emit("bench_gateway");

    let mut report = JsonReport::at_root("BENCH_kernels.json");
    let common = vec![
        ("requests", Value::num(N_REQ as f64)),
        ("n_gen", Value::num(N_GEN as f64)),
        (
            "token_time_ms",
            Value::num(TOKEN_TIME.as_secs_f64() * 1e3),
        ),
        (
            "note",
            Value::str_of(
                "real gateway + v3 codec over mock replicas (one \
                 sequential worker each); HTTP loopback end to end",
            ),
        ),
    ];
    report.add(
        "gateway_fanout_throughput",
        &t_two,
        0,
        Value::obj({
            let mut c = common.clone();
            c.push(("replicas", Value::num(2.0)));
            c.push(("ratio_2_vs_1", Value::num(ratio)));
            c.push(("ratio_basis", Value::str_of("min")));
            c.push((
                "requests_per_s",
                Value::num(N_REQ as f64 / t_two.p50()),
            ));
            c
        }),
    );
    report.add(
        "gateway_affinity_hit_rate",
        &t_aff,
        0,
        Value::obj({
            let mut c = common;
            c.push(("replicas", Value::num(2.0)));
            c.push(("sessions", Value::num(N_SESSIONS as f64)));
            c.push(("turns", Value::num(turns)));
            c.push(("hit_rate", Value::num(hit_rate)));
            c
        }),
    );
    report.write().expect("write BENCH_kernels.json");
    bench::note(
        "bench_gateway",
        &format!(
            "\n{N_REQ} concurrent generates: 1 replica {} vs 2 replicas {} \
             ({ratio:.2}x). Affinity hit rate over {turns} turns: \
             {hit_rate:.3}.",
            fmt_duration(t_one.p50()),
            fmt_duration(t_two.p50()),
        ),
    );
    println!("wrote BENCH_kernels.json (gateway_* records)");

    one.gateway.request_stop();
    two.gateway.request_stop();
}
