//! Shared-prefix copy-on-write bench: the PR 7 sharing argument made
//! measurable. Under snapshot-copy reuse, every sequence that continues a
//! common 1k-token prefix (a system prompt, a few-shot header) pays the
//! prefix's full quantized footprint again — packed pages, scales AND the
//! fp32 residual ring. A refcounted shared node charges those bytes ONCE:
//! each attached sequence holds only the private ring page(s) of its own
//! divergence, so the same byte budget holds several times more
//! concurrent continuations, and "making the next sequence ready" is an
//! O(1) attach instead of replaying the whole prefix (the `prefix_id`
//! TTFT win, measured here at the pool level). Pure-Rust (no artifacts),
//! runs everywhere. Emits the `prefix_*` records of `BENCH_kernels.json`.

use asymkv::kvcache::{CacheGeometry, CachePool};
use asymkv::quant::QuantPolicy;
use asymkv::util::bench::{self, fmt_duration, time_fn, JsonReport, Table};
use asymkv::util::json::Value;

// long-context geometry: a 1k-token shared prefix must be small next to
// the context limit, and G | R so ring pages are group-sized
const GEO: CacheGeometry = CacheGeometry {
    n_heads: 8,
    max_ctx: 4096,
    d_head: 64,
    group: 32,
    residual: 64,
};
const LAYERS: usize = 4;
/// the shared system-prompt prefix every sequence continues
const PREFIX_TOKENS: usize = 1000;
/// per-sequence private divergence (one ring page's worth)
const SUFFIX_TOKENS: usize = 16;
/// baseline fleet: the budget is sized to hold exactly this many
/// snapshot-copy sequences
const COPY_ACTIVE: usize = 8;

fn policy() -> QuantPolicy {
    QuantPolicy::kivi(LAYERS, 1) // the 1-bit flagship
}

/// Append `count` identical tokens to every layer of `id` (the accounting
/// only depends on counts, not values).
fn grow(pool: &CachePool, id: u64, count: usize) {
    let hd = GEO.n_heads * GEO.d_head;
    let row = vec![0.5f32; hd];
    pool.with_seq(id, |s| {
        for layer in &mut s.layers {
            for _ in 0..count {
                layer.append_token(&row, &row);
            }
        }
        s.pos += count;
    })
    .unwrap();
}

/// Freeze a PREFIX_TOKENS sequence into a shared node holding one
/// standalone reference (the `prefix_register` path, pool-level).
fn build_base(pool: &CachePool) -> std::sync::Arc<asymkv::kvcache::SeqBase> {
    let donor = pool.allocate(&policy()).unwrap();
    grow(pool, donor, PREFIX_TOKENS);
    let base = pool.share_seq(donor).unwrap();
    pool.retain_shared(&base).unwrap();
    pool.free(donor).unwrap();
    base
}

fn main() {
    let p = policy();

    // ---- per-sequence footprint: snapshot-copy vs shared attach ----
    let probe = CachePool::new(GEO, usize::MAX);
    let copy_bytes = {
        // a snapshot-copy continuation re-materializes prefix + suffix
        let id = probe.allocate(&p).unwrap();
        grow(&probe, id, PREFIX_TOKENS + SUFFIX_TOKENS);
        let b = probe.with_seq(id, |s| s.capacity_bytes()).unwrap();
        probe.free(id).unwrap();
        b
    };
    let base = build_base(&probe);
    let base_bytes = base.bytes();
    let shared_bytes = {
        // an attached continuation allocates only its private divergence
        let id = probe.allocate_attached(&base).unwrap();
        grow(&probe, id, SUFFIX_TOKENS);
        let b = probe.with_seq(id, |s| s.capacity_bytes()).unwrap();
        probe.free(id).unwrap();
        b
    };
    assert!(shared_bytes > 0, "suffix divergence must allocate CoW pages");
    let density_ratio = copy_bytes as f64 / shared_bytes as f64;
    assert!(
        density_ratio >= 4.0,
        "a shared-prefix continuation must cost >= 4x less than a \
         snapshot copy (got {copy_bytes} vs {shared_bytes} bytes)"
    );
    probe.release_shared(base.id).unwrap();

    // ---- fleet under a fixed budget: how many continuations fit ----
    let budget = COPY_ACTIVE * copy_bytes;
    let pool = CachePool::new(GEO, budget);
    let base = build_base(&pool);
    let mut ids = Vec::new();
    while pool.admit_attached(&base, SUFFIX_TOKENS).is_ok() {
        let id = pool.allocate_attached(&base).unwrap();
        grow(&pool, id, SUFFIX_TOKENS);
        ids.push(id);
    }
    let shared_active = ids.len();
    let st = pool.stats();
    assert_eq!(st.shared_segs, 1, "one unique node however many attach");
    assert_eq!(st.cow_breaks as usize, shared_active, "every fork diverged");
    assert!(
        st.shared_bytes_saved >= (shared_active as u64 - 1) * base_bytes as u64,
        "each attach past the first must save the node's bytes"
    );
    let fleet_ratio = shared_active as f64 / COPY_ACTIVE as f64;
    assert!(
        fleet_ratio >= 3.0,
        "the shared fleet must beat the snapshot-copy fleet >= 3x \
         (got {shared_active} vs {COPY_ACTIVE}; the per-seq density \
         gate above is the hard 4x)"
    );
    for id in ids.drain(..) {
        pool.free(id).unwrap();
    }
    pool.release_shared(base.id).unwrap();
    assert_eq!(pool.stats().in_use_bytes, 0, "fleet must fully release");

    let mut t = Table::new(
        "shared-prefix CoW: bytes per continuation of a 1k-token prefix",
        &["reuse strategy", "bytes/seq", "active @ budget", "vs copy"],
    );
    t.row(vec![
        "snapshot copy".into(),
        copy_bytes.to_string(),
        COPY_ACTIVE.to_string(),
        "1.0x".into(),
    ]);
    t.row(vec![
        "shared node (CoW)".into(),
        shared_bytes.to_string(),
        shared_active.to_string(),
        format!("{density_ratio:.1}x"),
    ]);

    let mut report = JsonReport::at_root("BENCH_kernels.json");
    let reps = bench::samples(20);
    let warm = bench::warmup(2);

    // ---- TTFT proxy: making the NEXT continuation decode-ready ----
    // snapshot copy replays the whole prefix into fresh pages; attach is
    // a refcount bump + zero-page SeqCache — the prefix_id fast path
    let pool = CachePool::new(GEO, usize::MAX);
    let base = build_base(&pool);
    let tm_copy = time_fn(warm, reps, || {
        let id = pool.allocate(&p).unwrap();
        grow(&pool, id, PREFIX_TOKENS);
        pool.free(id).unwrap();
        std::hint::black_box(id);
    });
    let tm_attach = time_fn(warm, reps, || {
        let id = pool.allocate_attached(&base).unwrap();
        pool.free(id).unwrap();
        std::hint::black_box(id);
    });
    let ttft_ratio = tm_copy.p50() / tm_attach.p50();
    t.row(vec![
        "copy: replay prefix".into(),
        copy_bytes.to_string(),
        "-".into(),
        fmt_duration(tm_copy.p50()),
    ]);
    t.row(vec![
        "attach: refcount bump".into(),
        "0".into(),
        "-".into(),
        fmt_duration(tm_attach.p50()),
    ]);

    report.add(
        "prefix_shared_density",
        &tm_copy,
        budget,
        Value::obj(vec![
            ("budget_bytes", Value::num(budget as f64)),
            ("prefix_tokens", Value::num(PREFIX_TOKENS as f64)),
            ("suffix_tokens", Value::num(SUFFIX_TOKENS as f64)),
            ("copy_seq_bytes", Value::num(copy_bytes as f64)),
            ("shared_seq_bytes", Value::num(shared_bytes as f64)),
            ("base_bytes", Value::num(base_bytes as f64)),
            ("copy_active", Value::num(COPY_ACTIVE as f64)),
            ("shared_active", Value::num(shared_active as f64)),
            ("density_ratio_vs_copy", Value::num(density_ratio)),
            ("fleet_ratio_vs_copy", Value::num(fleet_ratio)),
            ("layers", Value::num(LAYERS as f64)),
            ("policy", Value::str_of(p.name.clone())),
        ]),
    );
    report.add(
        "prefix_attach_ttft",
        &tm_attach,
        base_bytes,
        Value::obj(vec![
            ("prefix_tokens", Value::num(PREFIX_TOKENS as f64)),
            ("copy_ready_p50_s", Value::num(tm_copy.p50())),
            ("attach_ready_p50_s", Value::num(tm_attach.p50())),
            ("ttft_ratio_vs_copy", Value::num(ttft_ratio)),
            ("policy", Value::str_of(p.name.clone())),
        ]),
    );
    pool.release_shared(base.id).unwrap();

    t.emit("bench_prefix");
    bench::note(
        "bench_prefix",
        &format!(
            "\n{PREFIX_TOKENS}-token shared prefix, {SUFFIX_TOKENS}-token \
             divergence: {copy_bytes} bytes/seq snapshot-copy vs \
             {shared_bytes} shared ({density_ratio:.1}x denser); the same \
             budget holds {COPY_ACTIVE} copies or {shared_active} attached \
             continuations; next-sequence readiness {ttft_ratio:.0}x faster \
             by attach."
        ),
    );
    report.write().expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json (prefix_* records)");
}
