//! Trace-driven workload harness: the three ISSUE scenarios replayed
//! end-to-end, reporting serving-grade metrics (TTFT/TPOT percentiles,
//! goodput under SLO, stuck counts) plus the target's pressure counters
//! (preemptions, downshifts + bytes freed, hibernation spills/restores).
//!
//! * `trace_steady` — Poisson arrivals, mixed lengths, light session
//!   reuse, generous pool budget: the clean-latency baseline.
//! * `trace_bursty_cancel` — on/off burst phases, a cancel storm, slow
//!   SSE readers, and think-time gaps crossing the sim's idle-sweep
//!   threshold, so sessions hibernate between turns and restore on the
//!   next one.
//! * `trace_chaos_replica_kill` — the same replayer pointed at a REAL
//!   `Gateway` over two wire-faithful `MockReplica`s; one replica is
//!   hard-killed mid-run. In-flight streams must end with the typed
//!   `replica_unavailable` SSE error (never a hang — `stuck` stays 0)
//!   and later arrivals must complete on the survivor.
//!
//! The first two run on the real memory subsystem (budgeted pool, real
//! quantized folds, real spill files) via `workload::sim::SimServer`;
//! only the forward pass is simulated, so this runs artifact-free in CI.

use std::sync::Arc;
use std::time::{Duration, Instant};

use asymkv::gateway::testing::{http_sse, MockReplica, MockReplicaConfig};
use asymkv::gateway::{Gateway, GatewayConfig};
use asymkv::kvcache::{CacheGeometry, HibernateConfig};
use asymkv::quant::QuantPolicy;
use asymkv::util::bench::{self, JsonReport, Table, Timing};
use asymkv::util::json::Value;
use asymkv::workload::replay::{
    replay, ReplayConfig, ReplayTarget, RequestOutcome, RunReport,
    TargetStats,
};
use asymkv::workload::sim::{SimConfig, SimServer};
use asymkv::workload::trace::{
    generate_trace, Arrivals, LenDist, SessionProfile, TraceConfig,
    TraceRequest,
};

const GEO: CacheGeometry = CacheGeometry {
    n_heads: 2,
    max_ctx: 2048,
    d_head: 32,
    group: 32,
    residual: 64,
};
const LAYERS: usize = 4;

fn spill_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("asymkv-bench-trace-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Wrap a scenario's report + floor fields into one JSON record config.
fn record(
    report: &mut JsonReport,
    name: &str,
    run: &RunReport,
    extra: Vec<(&str, Value)>,
) {
    // the record's headline timing is the run wall clock; bytes/s is the
    // decode token throughput
    let t = Timing { samples: vec![run.wall_s] };
    let mut cfg = vec![
        ("stuck", Value::num(run.stuck as f64)),
        ("dropped", Value::num(0.0)), // asserted == 0 before recording
        ("spills", Value::num(run.stats.spills as f64)),
        ("restores", Value::num(run.stats.restores as f64)),
        ("downshifts", Value::num(run.stats.downshifts as f64)),
        (
            "downshift_bytes_freed",
            Value::num(run.stats.downshift_bytes_freed as f64),
        ),
        ("report", run.to_json()),
    ];
    cfg.extend(extra);
    report.add(name, &t, run.tokens, Value::obj(cfg));
}

fn summarize(table: &mut Table, scenario: &str, run: &RunReport) {
    table.row(vec![
        scenario.to_string(),
        run.n_requests.to_string(),
        format!("{}/{}/{}", run.completed, run.cancelled, run.failed),
        run.stuck.to_string(),
        format!("{:.1} ms", run.ttft_p50_s * 1e3),
        format!("{:.1} ms", run.ttft_p95_s * 1e3),
        format!("{:.1}", run.throughput_tok_s),
        format!("{:.1}", run.goodput_rps),
        format!("{}/{}", run.stats.spills, run.stats.restores),
        format!(
            "{} ({} B)",
            run.stats.downshifts, run.stats.downshift_bytes_freed
        ),
    ]);
}

// ----------------------------------------------------------------------
// scenarios 1+2: the artifact-free simulated server
// ----------------------------------------------------------------------

fn run_steady(n: usize) -> RunReport {
    let server = SimServer::start(SimConfig {
        geo: GEO,
        policy: QuantPolicy::kivi(LAYERS, 1),
        pool_budget: 256 << 20,
        token_time: Duration::from_micros(200),
        idle_timeout: Duration::from_secs(60), // no sweeps in-window
        hibernate: Some(HibernateConfig {
            dir: spill_dir("steady"),
            budget_bytes: 1 << 30,
        }),
    });
    let trace = generate_trace(&TraceConfig {
        seed: 0x57EAD,
        n_requests: n,
        arrivals: Arrivals::Poisson { rate: 150.0 },
        prompt_pairs: LenDist::Uniform(4, 16),
        n_gen: LenDist::Uniform(4, 12),
        sessions: Some(SessionProfile {
            fraction: 0.3,
            turns: LenDist::Fixed(2),
            think_s: (0.005, 0.01), // well inside the idle timeout
        }),
        prefix_frac: 0.0,
        cancel_frac: 0.0,
        cancel_after_s: 0.0,
        slow_reader_frac: 0.0,
    });
    let run = replay(server.as_ref(), &trace, &ReplayConfig::default());
    server.shutdown();
    assert_eq!(run.n_requests, trace.len(), "steady: requests dropped");
    assert_eq!(run.stuck, 0, "steady: stuck requests");
    assert_eq!(run.failed, 0, "steady: {:?}", run.errors);
    run
}

fn run_bursty_cancel(n: usize) -> RunReport {
    let server = SimServer::start(SimConfig {
        geo: GEO,
        policy: QuantPolicy::kivi(LAYERS, 1),
        pool_budget: 256 << 20,
        token_time: Duration::from_micros(200),
        // think-time gaps (80-120 ms) cross this: the sweeper spills the
        // session between turns and the next turn restores from disk
        idle_timeout: Duration::from_millis(20),
        hibernate: Some(HibernateConfig {
            dir: spill_dir("bursty"),
            budget_bytes: 1 << 30,
        }),
    });
    let trace = generate_trace(&TraceConfig {
        seed: 0xB0257,
        n_requests: n,
        arrivals: Arrivals::Bursty {
            base_rate: 40.0,
            burst_rate: 400.0,
            on_s: 0.05,
            off_s: 0.05,
        },
        prompt_pairs: LenDist::Uniform(4, 16),
        n_gen: LenDist::Uniform(4, 12),
        sessions: Some(SessionProfile {
            fraction: 0.6,
            turns: LenDist::Fixed(2),
            think_s: (0.08, 0.12),
        }),
        prefix_frac: 0.0,
        cancel_frac: 0.25, // the cancel storm
        cancel_after_s: 0.001,
        slow_reader_frac: 0.15,
    });
    let run = replay(server.as_ref(), &trace, &ReplayConfig::default());
    server.shutdown();
    assert_eq!(run.n_requests, trace.len(), "bursty: requests dropped");
    assert_eq!(run.stuck, 0, "bursty: stuck requests");
    assert!(run.cancelled > 0, "bursty: the cancel storm never fired");
    assert!(
        run.stats.spills >= 1 && run.stats.restores >= 1,
        "bursty: think-time never crossed the idle sweep \
         (spills {}, restores {})",
        run.stats.spills,
        run.stats.restores,
    );
    run
}

// ----------------------------------------------------------------------
// scenario 3: a real gateway fleet with a mid-run replica kill
// ----------------------------------------------------------------------

/// Replay adapter over the gateway's HTTP/SSE surface. `http_sse`
/// buffers the whole stream, so TTFT is not separately observable here
/// (reported equal to total); the Sim scenarios carry the TTFT/TPOT
/// percentiles, this scenario carries the failure-typing story.
struct GatewayTarget {
    addr: String,
}

impl ReplayTarget for GatewayTarget {
    fn run(&self, req: &TraceRequest) -> RequestOutcome {
        let t0 = Instant::now();
        let body = Value::obj(vec![
            ("prompt", Value::str_of(req.episode.prompt.clone())),
            ("n_gen", Value::num(req.n_gen as f64)),
            ("stream", Value::Bool(true)),
        ]);
        let mut out = RequestOutcome::default();
        match http_sse(&self.addr, "POST", "/v1/generate", Some(&body)) {
            Ok((status, events)) => {
                out.tokens =
                    events.iter().filter(|e| e.event == "token").count();
                out.total_s = t0.elapsed().as_secs_f64();
                out.ttft_s = out.total_s;
                match events.last() {
                    Some(e) if e.event == "done" => out.ok = true,
                    Some(e) if e.event == "error" => {
                        out.error = Some(
                            e.data
                                .get("error")
                                .get("code")
                                .as_str()
                                .unwrap_or("unknown")
                                .to_string(),
                        );
                    }
                    _ => out.error = Some(format!("http_{status}")),
                }
            }
            Err(_) => {
                out.total_s = t0.elapsed().as_secs_f64();
                out.error = Some("transport".to_string());
            }
        }
        out
    }

    fn stats(&self) -> TargetStats {
        TargetStats::default()
    }
}

fn run_chaos(n: usize) -> (RunReport, u64, usize) {
    let replicas: Vec<MockReplica> = (0..2)
        .map(|_| {
            MockReplica::spawn(MockReplicaConfig {
                n_layers: LAYERS,
                token_time: Duration::from_millis(4),
            })
            .unwrap()
        })
        .collect();
    let addrs: Vec<String> =
        replicas.iter().map(|r| r.addr().to_string()).collect();
    let gw = Arc::new(
        Gateway::bind("127.0.0.1:0", &addrs, GatewayConfig::default())
            .unwrap(),
    );
    let serve = gw.clone();
    std::thread::spawn(move || {
        let _ = serve.serve();
    });
    let target = GatewayTarget { addr: gw.local_addr() };

    let trace = generate_trace(&TraceConfig {
        seed: 0xC4405,
        n_requests: n,
        arrivals: Arrivals::Poisson { rate: 40.0 },
        prompt_pairs: LenDist::Fixed(4),
        n_gen: LenDist::Fixed(25), // ~100 ms streams: the kill lands mid-flight
        sessions: None,
        prefix_frac: 0.0,
        cancel_frac: 0.0,
        cancel_after_s: 0.0,
        slow_reader_frac: 0.0,
    });

    // the chaos knob: hard-kill replica 0 while streams are in flight
    let doomed = &replicas[0];
    let run = std::thread::scope(|s| {
        s.spawn(|| {
            std::thread::sleep(Duration::from_millis(150));
            doomed.kill();
        });
        replay(&target, &trace, &ReplayConfig::default())
    });
    let survivor_completed = replicas[1].served();
    gw.request_stop();

    assert_eq!(run.n_requests, trace.len(), "chaos: requests dropped");
    assert_eq!(run.stuck, 0, "chaos: a stream hung through the kill");
    let unavailable =
        run.errors.get("replica_unavailable").copied().unwrap_or(0);
    assert!(
        unavailable >= 1,
        "chaos: the kill produced no typed replica_unavailable \
         (errors: {:?})",
        run.errors,
    );
    assert!(
        survivor_completed >= 1 && run.completed >= 1,
        "chaos: nothing completed on the survivor",
    );
    (run, survivor_completed, unavailable)
}

fn main() {
    // smoke shrinks the traces, not the scenario structure
    let (n_steady, n_bursty, n_chaos) =
        if bench::smoke() { (12, 12, 8) } else { (48, 64, 20) };

    let steady = run_steady(n_steady);
    let bursty = run_bursty_cancel(n_bursty);
    let (chaos, survivor_completed, unavailable) = run_chaos(n_chaos);

    let mut t = Table::new(
        "trace replay harness: three scenarios",
        &[
            "scenario",
            "reqs",
            "ok/cancel/fail",
            "stuck",
            "TTFT p50",
            "TTFT p95",
            "tok/s",
            "goodput rps",
            "spill/restore",
            "downshifts",
        ],
    );
    summarize(&mut t, "steady (poisson)", &steady);
    summarize(&mut t, "bursty + cancel storm", &bursty);
    summarize(&mut t, "chaos (replica kill)", &chaos);
    t.emit("bench_trace");

    let mut report = JsonReport::at_root("BENCH_kernels.json");
    record(
        &mut report,
        "trace_steady",
        &steady,
        vec![
            ("scenario", Value::str_of("steady")),
            ("arrivals", Value::str_of("poisson rate=150/s")),
            ("policy", Value::str_of("kivi-1bit")),
            ("n_requests", Value::num(steady.n_requests as f64)),
        ],
    );
    record(
        &mut report,
        "trace_bursty_cancel",
        &bursty,
        vec![
            ("scenario", Value::str_of("bursty+cancel")),
            (
                "arrivals",
                Value::str_of("bursty 40/400 rps, 50ms on/off"),
            ),
            ("policy", Value::str_of("kivi-1bit")),
            ("cancel_frac", Value::num(0.25)),
            ("slow_reader_frac", Value::num(0.15)),
            ("n_requests", Value::num(bursty.n_requests as f64)),
        ],
    );
    record(
        &mut report,
        "trace_chaos_replica_kill",
        &chaos,
        vec![
            ("scenario", Value::str_of("chaos replica kill")),
            ("arrivals", Value::str_of("poisson rate=40/s")),
            ("replicas", Value::num(2.0)),
            ("kill_at_s", Value::num(0.15)),
            (
                "survivor_completed",
                Value::num(survivor_completed as f64),
            ),
            (
                "replica_unavailable_errors",
                Value::num(unavailable as f64),
            ),
            ("n_requests", Value::num(chaos.n_requests as f64)),
        ],
    );
    report.write().expect("write BENCH_kernels.json");

    bench::note(
        "bench_trace",
        &format!(
            "\nAll scenarios zero-stuck. Bursty: {} spills / {} restores \
             across think-time gaps, {} cancels, {} downshifts \
             ({} bytes freed). Chaos: {} typed replica_unavailable, \
             {} completed on the survivor.",
            bursty.stats.spills,
            bursty.stats.restores,
            bursty.cancelled,
            bursty.stats.downshifts,
            bursty.stats.downshift_bytes_freed,
            unavailable,
            survivor_completed,
        ),
    );
    println!("wrote BENCH_kernels.json (trace_* records)");
}
