//! Serving performance: end-to-end throughput/latency of the coordinator
//! under a request trace, across quantization policies and batching
//! ablations. (Not a paper table — the paper's system-side claim is memory;
//! this bench backs the §Perf deliverable and the batching design choices.)

use std::sync::Arc;

use asymkv::coordinator::{Coordinator, CoordinatorConfig, Request};
use asymkv::engine::Engine;
use asymkv::model::ByteTokenizer;
use asymkv::quant::QuantPolicy;
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::workload::trace::{generate_trace, TraceConfig};

fn run_trace(
    engine: Arc<Engine>,
    cfg: CoordinatorConfig,
    policy: &QuantPolicy,
    n_requests: usize,
) -> (f64, f64, f64) {
    let coord = Coordinator::start(engine, cfg);
    let tok = ByteTokenizer;
    // offline preset: all arrive at once (throughput measurement)
    let trace = generate_trace(&TraceConfig::recall_preset(
        0xBEEF, n_requests, 0.0, 12, 8,
    ));
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = trace
        .iter()
        .enumerate()
        .map(|(i, r)| {
            coord.submit(Request::greedy(
                i as u64,
                tok.encode(&r.episode.prompt),
                r.n_gen,
                policy.clone(),
            ))
        })
        .collect();
    let mut total_tokens = 0usize;
    for h in handles {
        let resp = h.wait();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        total_tokens += resp.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    coord.shutdown();
    (total_tokens as f64 / wall, m.ttft_p50_s, m.total_p95_s)
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Arc::new(Engine::new(rt, 2 << 30)?);
    let n = engine.manifest().n_layers;
    let n_req = 16;

    note("perf_serving", &format!(
        "\nServing bench — offline trace of {n_req} recall requests \
         (8 gen tokens each), model {}", engine.manifest().name));

    // --- policy comparison at the default batching config ---
    let mut t = Table::new(
        "serving throughput by policy (default batching)",
        &["policy", "tok/s", "TTFT p50", "total p95"],
    );
    for policy in [
        QuantPolicy::float32(n),
        QuantPolicy::kivi(n, 2),
        QuantPolicy::asymkv21(n, n / 2, 0),
        QuantPolicy::kivi(n, 1),
    ] {
        // warm-up pass compiles this policy's artifact variants outside the
        // measured window (lazy PJRT compilation would otherwise dominate)
        run_trace(engine.clone(), CoordinatorConfig::default(), &policy, 2);
        let (tput, ttft, p95) = run_trace(
            engine.clone(),
            CoordinatorConfig::default(),
            &policy,
            n_req,
        );
        t.row(vec![
            policy.name.clone(),
            format!("{tput:.1}"),
            format!("{:.0} ms", ttft * 1e3),
            format!("{:.0} ms", p95 * 1e3),
        ]);
    }
    t.emit("perf_serving");

    // --- batching ablation (the coordinator's own design choice) ---
    let mut t2 = Table::new(
        "batching ablation (AsymKV-l/0 policy)",
        &["max_batch", "tok/s", "TTFT p50", "total p95"],
    );
    let policy = QuantPolicy::asymkv21(n, n / 2, 0);
    run_trace(engine.clone(), CoordinatorConfig::default(), &policy, 2);
    for max_batch in [1usize, 2, 4, 8] {
        let cfg = CoordinatorConfig {
            max_active: 16,
            max_batch,
            batch_window: std::time::Duration::from_millis(2),
            prefix_cache_bytes: 0,
            downshift: true,
        };
        let (tput, ttft, p95) = run_trace(engine.clone(), cfg, &policy, n_req);
        t2.row(vec![
            max_batch.to_string(),
            format!("{tput:.1}"),
            format!("{:.0} ms", ttft * 1e3),
            format!("{:.0} ms", p95 * 1e3),
        ]);
    }
    t2.emit("perf_serving");
    note("perf_serving",
         "\nExpected: batched decode amortizes per-call PJRT overhead — \
          throughput rises with max_batch until the artifact batch size \
          saturates.");
    Ok(())
}
