//! Single-connection serving throughput: **multiplexed** (v3 tagged
//! concurrent requests) vs **serialized** (v2 one-line-in/one-line-out)
//! submission over ONE socket — the measurement behind the v3 wire
//! protocol's existence.
//!
//! Two parts:
//!
//! 1. **Pure-Rust transport harness** (runs everywhere, emits the
//!    CI-asserted records): a loopback line server speaking the REAL
//!    `asymkv::api` codec whose backend is a fixed per-request service
//!    time — the stand-in for a batch-friendly engine, where concurrent
//!    requests overlap their service exactly the way policy-homogeneous
//!    decode batches do. The serialized client pays N × (service + rtt)
//!    because each request must fully round-trip before the next line is
//!    even sent; the multiplexed client submits all N tagged requests up
//!    front on the same socket and pays ~service + N × frame overhead.
//! 2. **End-to-end** (needs AOT artifacts; skips cleanly without them):
//!    the real Server/Engine — N concurrent generates through
//!    [`MuxClient`] vs the same N through the blocking [`Client`].
//!
//! Records: `server_mux_single_conn`, `server_serialized_single_conn`
//! (+ `server_e2e_{mux,serialized}` with artifacts); CI's bench-smoke job
//! asserts `server_mux_single_conn.config.ratio_mux_vs_serialized >= 2`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use asymkv::api::{
    self, ApiRequest, ApiResponse, Frame, GenerateSpec, GenerationResult,
    Proto,
};
use asymkv::server::{Client, MuxClient};
use asymkv::util::bench::{self, fmt_duration, time_fn, JsonReport, Table};
use asymkv::util::json::Value;

/// Requests per measured run (one socket).
const N_REQ: usize = 32;
/// Simulated per-request service time for the transport harness. Large
/// enough that per-request thread-spawn cost (the mock's, like the real
/// server's, worker-per-request model) stays a small fraction of it.
const SERVICE: Duration = Duration::from_millis(5);
/// Layer count handed to the codec (no policies are sent; any value works).
const N_LAYERS: usize = 4;

fn fake_result(id: u64) -> GenerationResult {
    GenerationResult {
        id,
        text: "ok".into(),
        tokens: vec![111, 107],
        ttft_s: 0.001,
        total_s: 0.002,
        error: None,
    }
}

/// Loopback mock server: real codec, simulated engine. v3 generation
/// lines get a worker thread each (service times overlap — the
/// batch-friendly regime); v1/v2 lines are served inline on the reader
/// thread (strict request→reply serialization, exactly like the real
/// server). Exits when the process does.
fn spawn_mock_server(service: Duration) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            std::thread::spawn(move || mock_conn(stream, service));
        }
    });
    addr
}

fn mock_conn(stream: TcpStream, service: Duration) {
    stream.set_nodelay(true).ok();
    let Ok(rstream) = stream.try_clone() else { return };
    let mut reader = BufReader::new(rstream);
    let out = Arc::new(Mutex::new(stream));
    let mut line = String::new();
    let mut next_id = 1u64;
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let Ok(frame) = api::decode_frame(trimmed, N_LAYERS) else { continue };
        let id = next_id;
        next_id += 1;
        match frame {
            Frame { proto: Proto::V3, tag: Some(tag), req } => match req {
                ApiRequest::Generate(_) => {
                    // concurrent service: workers sleep in parallel
                    let out = out.clone();
                    std::thread::spawn(move || {
                        std::thread::sleep(service);
                        let v = api::encode_response_tagged(
                            &ApiResponse::Generation(fake_result(id)),
                            tag,
                        );
                        let _ =
                            writeln!(out.lock().unwrap(), "{v}");
                    });
                }
                _ => {
                    let v = api::encode_response_tagged(&ApiResponse::Pong, tag);
                    let _ = writeln!(out.lock().unwrap(), "{v}");
                }
            },
            Frame { proto, req, .. } => {
                // serialized service: the reader thread IS the pipeline
                let v = match req {
                    ApiRequest::Generate(_) => {
                        std::thread::sleep(service);
                        api::encode_response(
                            &ApiResponse::Generation(fake_result(id)),
                            proto,
                        )
                    }
                    _ => api::encode_response(&ApiResponse::Pong, proto),
                };
                let _ = writeln!(out.lock().unwrap(), "{v}");
            }
        }
    }
}

fn gen_spec(i: usize) -> GenerateSpec {
    GenerateSpec {
        prompt: format!("## REQ:{i} ## REQ:"),
        n_gen: 4,
        ..Default::default()
    }
}

/// Serialized: one request fully round-trips before the next is sent.
fn run_serialized(addr: &str, n: usize) {
    let mut client = Client::connect(addr).expect("connect");
    for i in 0..n {
        let v = client
            .send(&ApiRequest::Generate(gen_spec(i)))
            .expect("serialized reply");
        // v2 errors are objects, not strings — compare against Null so a
        // failed request can never masquerade as throughput
        assert_eq!(v.get("error"), &Value::Null, "{v}");
    }
}

/// Multiplexed: all requests in flight at once on the same socket.
fn run_mux(addr: &str, n: usize) {
    let mux = MuxClient::connect(addr).expect("connect");
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            mux.submit(&ApiRequest::Generate(gen_spec(i))).expect("submit")
        })
        .collect();
    for p in pendings {
        let v = p.wait_done().expect("mux reply");
        assert_eq!(v.get("error"), &Value::Null, "{v}");
    }
}

fn main() {
    let addr = spawn_mock_server(SERVICE);
    let reps = bench::samples(10);
    let warm = bench::warmup(2);

    // approximate single-request wire traffic (request line + reply line)
    let wire_bytes = {
        let req = api::encode_request_tagged(
            &ApiRequest::Generate(gen_spec(0)),
            1,
        )
        .to_string()
        .len();
        let reply = api::encode_response_tagged(
            &ApiResponse::Generation(fake_result(1)),
            1,
        )
        .to_string()
        .len();
        (req + reply + 2) * N_REQ
    };

    let t_ser = time_fn(warm, reps, || run_serialized(&addr, N_REQ));
    let t_mux = time_fn(warm, reps, || run_mux(&addr, N_REQ));
    // min-over-samples: the structural ratio. Serialized wall time is
    // bounded below by N × service no matter how lucky a sample gets,
    // while descheduling stalls (thread-spawn storms on small CI boxes)
    // only ever inflate samples — so min/min measures the architecture,
    // not the box's scheduler noise.
    let ratio = t_ser.min() / t_mux.min();
    let rps_ser = N_REQ as f64 / t_ser.p50();
    let rps_mux = N_REQ as f64 / t_mux.p50();

    let mut t = Table::new(
        "single-connection throughput: multiplexed (v3) vs serialized (v2)",
        &["mode", "requests", "wall (p50)", "req/s", "vs serialized"],
    );
    t.row(vec![
        "serialized (v2)".into(),
        N_REQ.to_string(),
        fmt_duration(t_ser.p50()),
        format!("{rps_ser:.0}"),
        "1.0x".into(),
    ]);
    t.row(vec![
        "multiplexed (v3)".into(),
        N_REQ.to_string(),
        fmt_duration(t_mux.p50()),
        format!("{rps_mux:.0}"),
        format!("{ratio:.1}x"),
    ]);

    assert!(
        ratio >= 2.0,
        "multiplexed submission must be >= 2x serialized on one socket \
         (got {ratio:.2}x: serialized min {:.4}s vs mux min {:.4}s)",
        t_ser.min(),
        t_mux.min()
    );

    let mut report = JsonReport::at_root("BENCH_kernels.json");
    let cfg_common = |mode: &str| {
        vec![
            ("mode", Value::str_of(mode)),
            ("requests", Value::num(N_REQ as f64)),
            ("service_ms", Value::num(SERVICE.as_secs_f64() * 1e3)),
            (
                "note",
                Value::str_of(
                    "loopback transport harness: real api codec, \
                     fixed-service backend (concurrent service = the \
                     batch-friendly engine regime)",
                ),
            ),
        ]
    };
    report.add(
        "server_serialized_single_conn",
        &t_ser,
        wire_bytes,
        Value::obj({
            let mut c = cfg_common("serialized-v2");
            c.push(("requests_per_s", Value::num(rps_ser)));
            c
        }),
    );
    report.add(
        "server_mux_single_conn",
        &t_mux,
        wire_bytes,
        Value::obj({
            let mut c = cfg_common("multiplexed-v3");
            c.push(("requests_per_s", Value::num(rps_mux)));
            c.push(("ratio_mux_vs_serialized", Value::num(ratio)));
            c.push(("ratio_basis", Value::str_of("min")));
            c
        }),
    );

    // ---- end-to-end over the real engine (artifact-gated) -------------
    e2e(&mut t, &mut report);

    t.emit("bench_server");
    bench::note(
        "bench_server",
        &format!(
            "\nOne socket, {N_REQ} requests, {}ms simulated service: \
             serialized {} vs multiplexed {} p50 ({ratio:.1}x).",
            SERVICE.as_millis(),
            fmt_duration(t_ser.p50()),
            fmt_duration(t_mux.p50()),
        ),
    );
    report.write().expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json (server_* records)");
}

/// Real Server/Engine A/B when artifacts are present: the multiplexed
/// client keeps the continuous-batching scheduler's decode batches full
/// from ONE socket; the serialized client starves them.
fn e2e(t: &mut Table, report: &mut JsonReport) {
    use asymkv::coordinator::{Coordinator, CoordinatorConfig};
    use asymkv::engine::Engine;
    use asymkv::runtime::Runtime;
    use asymkv::server::Server;

    let dir =
        std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("[bench_server] artifacts unavailable ({e}); skipping e2e A/B");
            return;
        }
    };
    let engine = match Engine::new(rt, 1 << 30) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            println!("[bench_server] engine unavailable ({e}); skipping e2e A/B");
            return;
        }
    };
    let coord = Coordinator::start(engine, CoordinatorConfig::default());
    let server = Arc::new(Server::bind(coord, "127.0.0.1:0").unwrap());
    let addr = server.local_addr();
    {
        let srv = server.clone();
        std::thread::spawn(move || srv.serve());
    }
    let n = 8usize;
    let reps = bench::samples(5);
    let warm = bench::warmup(1);
    let t_ser = time_fn(warm, reps, || run_serialized(&addr, n));
    let t_mux = time_fn(warm, reps, || run_mux(&addr, n));
    let ratio = t_ser.min() / t_mux.min();
    t.row(vec![
        "e2e serialized".into(),
        n.to_string(),
        fmt_duration(t_ser.p50()),
        format!("{:.0}", n as f64 / t_ser.mean()),
        "1.0x".into(),
    ]);
    t.row(vec![
        "e2e multiplexed".into(),
        n.to_string(),
        fmt_duration(t_mux.p50()),
        format!("{:.0}", n as f64 / t_mux.mean()),
        format!("{ratio:.1}x"),
    ]);
    let cfg = |mode: &str, extra: Option<f64>| {
        let mut c = vec![
            ("mode", Value::str_of(mode)),
            ("requests", Value::num(n as f64)),
            ("n_gen", Value::num(4.0)),
            ("artifacts", Value::str_of(dir.clone())),
        ];
        if let Some(r) = extra {
            c.push(("ratio_mux_vs_serialized", Value::num(r)));
        }
        Value::obj(c)
    };
    report.add("server_e2e_serialized", &t_ser, 0, cfg("serialized-v2", None));
    report.add("server_e2e_mux", &t_mux, 0, cfg("multiplexed-v3", Some(ratio)));
    server.request_stop();
}
