//! Calibration-subsystem benches, pure-Rust (no artifacts needed), so they
//! run everywhere including CI's bench-smoke job.
//!
//! 1. In-place code-domain requant (the pressure downshift's kernel) vs
//!    the golden refold-from-float path it replaces byte-identically
//!    (scalar `unfold_*`@high → `fold_*`@low). Emits
//!    `requant_inplace_{k,v}_<high>to<low>` records whose
//!    `ratio_vs_refold` uses min-over-samples (structural,
//!    scheduler-noise robust); CI gates the (2→1) pairs at ≥ 2×.
//! 2. The budget solver's frontier: solve time across a budget sweep on a
//!    synthetic 32-layer profile over the full 4×4 grid, with a
//!    monotonicity audit of the predicted-damage frontier (more budget
//!    must never predict more damage).

use asymkv::calib::{profile_synthetic, solve_budget};
use asymkv::quant::kernels::requant::{requant_k_group, requant_v_group};
use asymkv::quant::kernels::{
    fold_k_group_with, fold_v_group_with, packed_len, unfold_k_group_with,
    unfold_v_group_with, GroupParams, KernelMode,
};
use asymkv::quant::QuantPolicy;
use asymkv::util::bench::{self, fmt_duration, time_fn, JsonReport, Table};
use asymkv::util::json::Value;
use asymkv::util::rng::SplitMix;

const PAIRS: [(u8, u8); 3] = [(2, 1), (4, 1), (8, 2)];

fn zeroed(n: usize) -> Vec<GroupParams> {
    vec![GroupParams { scale: 0.0, zero: 0.0 }; n]
}

fn main() {
    let (g, dh, g2) = (32usize, 128usize, 32usize);
    let n_groups: usize = if bench::smoke() { 4 } else { 64 };
    let reps = bench::samples(200);
    let warm = bench::warmup(10);
    let mut rng = SplitMix::new(0xCA11B);
    let xs: Vec<f32> = rng.normal_f32_vec(n_groups * g * dh);

    bench::note(
        "bench_calib",
        &format!(
            "\nIn-place requant vs refold-from-float — {n_groups} cold groups \
             of [{g}, {dh}] (g2={g2}), {reps} samples"
        ),
    );
    let mut t = Table::new(
        "downshift kernel: requant in place vs golden refold (per region)",
        &["side", "pair", "refold p50", "requant p50", "ratio (min/min)"],
    );
    let mut report = JsonReport::at_root("BENCH_kernels.json");
    let float_bytes = n_groups * g * dh * 4;

    for (high, low) in PAIRS {
        let kernel_cfg = |ratio: f64, refold_p50: f64| {
            Value::obj(vec![
                ("g", Value::num(g as f64)),
                ("dh", Value::num(dh as f64)),
                ("g2", Value::num(g2 as f64)),
                ("n_groups", Value::num(n_groups as f64)),
                ("high", Value::num(high as f64)),
                ("low", Value::num(low as f64)),
                ("baseline", Value::str_of("scalar unfold@high + fold@low (golden)")),
                ("refold_p50_s", Value::num(refold_p50)),
                ("ratio_vs_refold", Value::num(ratio)),
            ])
        };

        // ---- K side: [G·bits/8, Dh] per-channel layout -----------------
        let rows_h = packed_len(g, high);
        let rows_l = packed_len(g, low);
        let mut k_hi = vec![0u8; n_groups * rows_h * dh];
        let mut kp_hi = zeroed(n_groups * dh);
        for gi in 0..n_groups {
            fold_k_group_with(
                KernelMode::Scalar,
                &xs[gi * g * dh..(gi + 1) * g * dh],
                g,
                dh,
                high,
                &mut k_hi[gi * rows_h * dh..(gi + 1) * rows_h * dh],
                &mut kp_hi[gi * dh..(gi + 1) * dh],
            );
        }
        let mut floats = vec![0f32; g * dh];
        let mut out_pk = vec![0u8; rows_l * dh];
        let mut out_p = zeroed(dh);
        let t_refold = time_fn(warm, reps, || {
            for gi in 0..n_groups {
                unfold_k_group_with(
                    KernelMode::Scalar,
                    &k_hi[gi * rows_h * dh..(gi + 1) * rows_h * dh],
                    g,
                    dh,
                    high,
                    &kp_hi[gi * dh..(gi + 1) * dh],
                    &mut floats,
                );
                fold_k_group_with(
                    KernelMode::Scalar, &floats, g, dh, low, &mut out_pk, &mut out_p,
                );
                std::hint::black_box(&out_pk);
            }
        });
        let t_requant = time_fn(warm, reps, || {
            for gi in 0..n_groups {
                requant_k_group(
                    &k_hi[gi * rows_h * dh..(gi + 1) * rows_h * dh],
                    &kp_hi[gi * dh..(gi + 1) * dh],
                    g,
                    dh,
                    high,
                    low,
                    &mut out_pk,
                    &mut out_p,
                );
                std::hint::black_box(&out_pk);
            }
        });
        let ratio = t_refold.min() / t_requant.min();
        t.row(vec![
            "K".into(),
            format!("{high}->{low}"),
            fmt_duration(t_refold.p50()),
            fmt_duration(t_requant.p50()),
            format!("{ratio:.2}x"),
        ]);
        report.add(
            &format!("requant_inplace_k_{high}to{low}"),
            &t_requant,
            float_bytes,
            kernel_cfg(ratio, t_refold.p50()),
        );

        // ---- V side: [G, Dh·bits/8] per-token layout -------------------
        let bpt_h = packed_len(dh, high);
        let bpt_l = packed_len(dh, low);
        let dg = dh / g2;
        let mut v_hi = vec![0u8; n_groups * g * bpt_h];
        let mut vp_hi = zeroed(n_groups * g * dg);
        for gi in 0..n_groups {
            fold_v_group_with(
                KernelMode::Scalar,
                &xs[gi * g * dh..(gi + 1) * g * dh],
                g,
                dh,
                g2,
                high,
                &mut v_hi[gi * g * bpt_h..(gi + 1) * g * bpt_h],
                &mut vp_hi[gi * g * dg..(gi + 1) * g * dg],
            );
        }
        let mut out_vpk = vec![0u8; g * bpt_l];
        let mut out_vp = zeroed(g * dg);
        let t_refold_v = time_fn(warm, reps, || {
            for gi in 0..n_groups {
                unfold_v_group_with(
                    KernelMode::Scalar,
                    &v_hi[gi * g * bpt_h..(gi + 1) * g * bpt_h],
                    g,
                    dh,
                    g2,
                    high,
                    &vp_hi[gi * g * dg..(gi + 1) * g * dg],
                    &mut floats,
                );
                fold_v_group_with(
                    KernelMode::Scalar, &floats, g, dh, g2, low, &mut out_vpk, &mut out_vp,
                );
                std::hint::black_box(&out_vpk);
            }
        });
        let t_requant_v = time_fn(warm, reps, || {
            for gi in 0..n_groups {
                requant_v_group(
                    &v_hi[gi * g * bpt_h..(gi + 1) * g * bpt_h],
                    &vp_hi[gi * g * dg..(gi + 1) * g * dg],
                    g,
                    dh,
                    g2,
                    high,
                    low,
                    &mut out_vpk,
                    &mut out_vp,
                );
                std::hint::black_box(&out_vpk);
            }
        });
        let ratio_v = t_refold_v.min() / t_requant_v.min();
        t.row(vec![
            "V".into(),
            format!("{high}->{low}"),
            fmt_duration(t_refold_v.p50()),
            fmt_duration(t_requant_v.p50()),
            format!("{ratio_v:.2}x"),
        ]);
        report.add(
            &format!("requant_inplace_v_{high}to{low}"),
            &t_requant_v,
            float_bytes,
            kernel_cfg(ratio_v, t_refold_v.p50()),
        );
    }
    t.emit("bench_calib");

    // ---- budget solver frontier ---------------------------------------
    let (n_layers, n_heads, d_head, group) = (32usize, 8usize, 64usize, 32usize);
    let bits = [1u8, 2, 4];
    let n_tokens = if bench::smoke() { 64 } else { 160 };
    let profile =
        profile_synthetic(n_layers, n_heads, d_head, group, n_tokens, 0xC0FFEE, &bits);
    let mut grid: Vec<(u8, u8)> = Vec::new();
    for k in [0u8, 1, 2, 4] {
        for v in [0u8, 1, 2, 4] {
            grid.push((k, v));
        }
    }
    let floor = QuantPolicy::kivi(n_layers, 1).bytes_per_token(n_heads, d_head, group);
    let budgets: Vec<usize> = [100, 105, 110, 125, 150, 200, 400, 1600]
        .iter()
        .map(|pct| floor * pct / 100)
        .collect();

    let mut ft = Table::new(
        "solver frontier (32 layers, full 4x4 grid)",
        &["budget B/tok", "spent B/tok", "damage", "upgrades", "solve p50"],
    );
    let mut last_damage = f64::INFINITY;
    let mut monotone = true;
    let mut total = asymkv::util::bench::Timing { samples: Vec::new() };
    for &budget in &budgets {
        let tm = time_fn(warm, reps, || {
            let s = solve_budget(&profile, &grid, n_heads, d_head, group, budget)
                .expect("budget >= floor must be solvable");
            std::hint::black_box(&s);
        });
        let s = solve_budget(&profile, &grid, n_heads, d_head, group, budget).unwrap();
        if s.predicted_damage > last_damage + 1e-12 {
            monotone = false;
        }
        last_damage = s.predicted_damage;
        ft.row(vec![
            budget.to_string(),
            s.bytes_per_token.to_string(),
            format!("{:.4}", s.predicted_damage),
            s.steps.len().to_string(),
            fmt_duration(tm.p50()),
        ]);
        total.samples.extend(tm.samples);
    }
    assert!(monotone, "predicted-damage frontier must be monotone in budget");
    ft.emit("bench_calib");
    report.add(
        "calib_solver_frontier",
        &total,
        n_layers * grid.len(),
        Value::obj(vec![
            ("n_layers", Value::num(n_layers as f64)),
            ("grid_pairs", Value::num(grid.len() as f64)),
            ("budgets", Value::num(budgets.len() as f64)),
            ("floor_bytes_per_token", Value::num(floor as f64)),
            ("monotone", Value::Bool(monotone)),
            (
                "note",
                Value::str_of(
                    "per-solve timing pooled over the budget sweep; damage \
                     frontier asserted monotone in budget",
                ),
            ),
        ]),
    );

    report.write().expect("writing BENCH_kernels.json");
}
