//! Fig. 4 — Memory variation of AsymKV.
//!
//! Paper: peak GPU memory at batch 48 (7b) / 36 (13b), generation length
//! 4096, while ramping l_k from 0→L with l_v = 0, then l_v from 0→L with
//! l_k = L. Memory grows ~linearly; the quality-parity AsymKV point saves
//! 6-10.4 GB vs KIVI-2bit.
//!
//! Here: EXACT bytes from the bit-packed cache pool (packed data + group
//! scales/zeros + fp32 residual window) for a batch of sequences filled to
//! the full context — measured by allocation, not modelled. The same ramp,
//! plus the quality-parity points from the Table 1/2 benches.

use std::sync::Arc;

use asymkv::engine::Engine;
use asymkv::quant::QuantPolicy;
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::util::rng::SplitMix;

fn fill_and_measure(
    engine: &Engine,
    policy: &QuantPolicy,
    batch: usize,
) -> anyhow::Result<(usize, usize)> {
    // allocate `batch` sequences and stream tokens to full context so the
    // packed regions + residual windows are genuinely populated
    let m = engine.manifest();
    let (h, dh) = (m.n_heads, m.d_head);
    let total = m.max_ctx + m.residual - 1;
    let mut rng = SplitMix::new(0xF164);
    let mut ids = Vec::new();
    for _ in 0..batch {
        ids.push(engine.create_seq(policy)?);
    }
    for &id in &ids {
        engine.with_seq(id, |seq| {
            let k: Vec<f32> = rng.normal_f32_vec(h * dh);
            let v: Vec<f32> = rng.normal_f32_vec(h * dh);
            for layer in &mut seq.layers {
                for _ in 0..total {
                    layer.append_token(&k, &v);
                }
            }
        })?;
    }
    let used: usize = ids
        .iter()
        .map(|&id| engine.with_seq(id, |s| s.used_bytes()).unwrap())
        .sum();
    let cap = engine.pool.stats().in_use_bytes;
    for id in ids {
        engine.free_seq(id)?;
    }
    Ok((used, cap))
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 8 << 30)?;
    let m = engine.manifest();
    let n = m.n_layers;
    let batch = 8; // paper: 48/36 on 80 GB; scaled to this testbed

    note("fig4_memory", &format!(
        "\nFig. 4 reproduction — exact packed-cache bytes, batch {batch}, \
         cache filled to {} tokens, model {} \
         (paper: batch 48/36, gen 4096, A800 80 GB)",
        m.max_ctx + m.residual - 1, m.name));

    let mut t = Table::new(
        "Fig.4: cache memory vs (l_k, l_v) ramp",
        &["config", "used MiB", "alloc MiB", "vs KIVI-2bit"],
    );
    let mib = |b: usize| b as f64 / (1024.0 * 1024.0);
    let (kivi_used, _) =
        fill_and_measure(&engine, &QuantPolicy::kivi(n, 2), batch)?;

    let mut ramp = Vec::new();
    for lk in 0..=n {
        ramp.push(QuantPolicy::asymkv21(n, lk, 0));
    }
    for lv in 1..=n {
        ramp.push(QuantPolicy::asymkv21(n, n, lv));
    }
    let mut used_series = Vec::new();
    for p in &ramp {
        let (used, cap) = fill_and_measure(&engine, p, batch)?;
        used_series.push(used);
        t.row(vec![
            p.name.clone(),
            format!("{:.2}", mib(used)),
            format!("{:.2}", mib(cap)),
            format!("{:+.1}%", (used as f64 / kivi_used as f64 - 1.0) * 100.0),
        ]);
    }
    let (float_used, _) =
        fill_and_measure(&engine, &QuantPolicy::float32(n), batch)?;
    t.row(vec!["float".into(), format!("{:.2}", mib(float_used)),
               "-".into(),
               format!("{:+.1}%", (float_used as f64 / kivi_used as f64 - 1.0) * 100.0)]);
    t.emit("fig4_memory");

    // linearity + the paper's savings claim at the quality-parity points
    let monotone = used_series.windows(2).all(|w| w[1] >= w[0]);
    let parity_normal = QuantPolicy::asymkv21(n, n / 2, 0); // Tab.1 parity
    let (parity_used, _) = fill_and_measure(&engine, &parity_normal, batch)?;
    note("fig4_memory", &format!(
        "\nPaper shape: ramp is monotone ({}), endpoint = KIVI-2bit \
         ({:.2} vs {:.2} MiB), and the Tab.1 quality-parity point \
         ({}) saves {:.2} MiB ({:.0}%) of cache vs KIVI-2bit \
         (paper: 9.0/10.4 GB at Llama scale).",
        if monotone { "yes" } else { "NO" },
        mib(*used_series.last().unwrap()),
        mib(kivi_used),
        parity_normal.name,
        mib(kivi_used.saturating_sub(parity_used)),
        (1.0 - parity_used as f64 / kivi_used as f64) * 100.0));

    // ---- the paper's ABSOLUTE numbers, analytically at Llama geometry ----
    // Our byte accounting, evaluated at the paper's exact setup: Llama-2-7b
    // (32 layers, 32 heads × 128) batch 48 and Llama-2-13b (40 layers,
    // 40 × 128) batch 36, generation length 4096 (paper §5.2.3 / §A.1).
    let gib = |b: f64| b / (1024.0 * 1024.0 * 1024.0);
    let mut t3 = Table::new(
        "Fig.4 at paper scale (analytic, our byte accounting)",
        &["model", "config", "cache GiB", "saving vs KIVI-2bit"],
    );
    for (name, layers, heads, dh, bsz, parity_lk) in [
        ("Llama-2-7b", 32usize, 32usize, 128usize, 48usize, 16usize),
        ("Llama-2-13b", 40, 40, 128, 36, 20),
    ] {
        let tokens = 4096usize;
        let bytes = |p: &QuantPolicy| -> f64 {
            (p.bytes_per_token(heads, dh, m.group) * tokens * bsz) as f64
        };
        let kivi = bytes(&QuantPolicy::kivi(layers, 2));
        for p in [
            QuantPolicy::float32(layers),
            QuantPolicy::kivi(layers, 2),
            QuantPolicy::asymkv21(layers, parity_lk, 0), // Tab.1 parity
            QuantPolicy::asymkv21(layers, layers, 0),    // Tab.2 parity
            QuantPolicy::kivi(layers, 1),
        ] {
            let b = bytes(&p);
            t3.row(vec![
                name.into(),
                p.name.clone(),
                format!("{:.2}", gib(b)),
                format!("{:.2} GiB", gib(kivi - b)),
            ]);
        }
    }
    t3.emit("fig4_memory");
    note("fig4_memory",
         "\nPaper reports: 7b saves 9.0 GB (normal-ctx parity) / 6.0 GB \
          (long-ctx parity); 13b saves 10.4 / 7.0 GB vs KIVI-2bit. Compare \
          with the analytic rows above (same ordering, same magnitude).");
    Ok(())
}
