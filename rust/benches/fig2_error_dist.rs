//! Fig. 2 — Distribution of the attention-output error from K-quantization
//! vs V-quantization, for three decoder layers.
//!
//! Paper: the key-quantization error distribution is "more sparse around 0"
//! (heavier tails) than the value-quantization error, hence the larger MSE.
//! Here: histograms of the per-element output error on real activations of
//! the pretrained `small` model, plus the fraction of mass near zero.

use std::sync::Arc;

use asymkv::analysis;
use asymkv::engine::Engine;
use asymkv::model::ByteTokenizer;
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::util::rng::SplitMix;
use asymkv::util::stats::variance;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();

    let tok = ByteTokenizer;
    // aggregate several prompts for enough error samples per layer
    let mut per_layer_k: Vec<Vec<f32>> = vec![vec![]; m.n_layers];
    let mut per_layer_v: Vec<Vec<f32>> = vec![vec![]; m.n_layers];
    for seed in 0..6u64 {
        let mut rng = SplitMix::new(0xF162 + seed);
        // retrieval positions (see fig1_mse_stages for why)
        let ep = asymkv::workload::tasks::recall_episode(&mut rng, 18);
        let acts = analysis::collect_activations(&engine, &tok.encode(&ep.prompt))?;
        for a in &acts {
            let s = analysis::stage_mse(&engine, a, 2)?;
            per_layer_k[a.layer].extend(&s.err_k);
            per_layer_v[a.layer].extend(&s.err_v);
        }
    }

    // pick three layers like the paper (early / middle / late)
    let picks = [0, m.n_layers / 2, m.n_layers - 1];
    note("fig2_error_dist", &format!(
        "\nFig. 2 reproduction — output-error distributions, model {}, \
         2-bit, layers {:?} (paper: 3 Llama-2 layers)", m.name, picks));

    let mut t = Table::new(
        "Fig.2: error-distribution summary (K vs V quantization)",
        &["layer", "source", "variance", "frac |e| < σ/2", "frac |e| > 2σ"],
    );
    for &l in &picks {
        for (name, errs) in [("K", &per_layer_k[l]), ("V", &per_layer_v[l])] {
            let var = variance(errs);
            let sd = var.sqrt();
            let n = errs.len() as f64;
            let near = errs.iter().filter(|e| (e.abs() as f64) < sd / 2.0).count()
                as f64 / n;
            let tail = errs.iter().filter(|e| (e.abs() as f64) > 2.0 * sd).count()
                as f64 / n;
            t.row(vec![
                l.to_string(),
                name.to_string(),
                format!("{var:.3e}"),
                format!("{near:.3}"),
                format!("{tail:.3}"),
            ]);
        }
    }
    t.emit("fig2_error_dist");

    // full histogram for the middle layer
    let l = picks[1];
    let s = analysis::StageMse {
        layer: l,
        bits: 2,
        mse_k: [0.0; 4],
        mse_v: [0.0; 4],
        err_k: per_layer_k[l].clone(),
        err_v: per_layer_v[l].clone(),
    };
    let (hk, hv) = analysis::error_histograms(&s, 15);
    note("fig2_error_dist", &format!("\nlayer {l} K-quant error histogram:"));
    note("fig2_error_dist", &hk.render(40));
    note("fig2_error_dist", &format!("layer {l} V-quant error histogram:"));
    note("fig2_error_dist", &hv.render(40));

    let vk: f64 = picks.iter().map(|&l| variance(&per_layer_k[l])).sum();
    let vv: f64 = picks.iter().map(|&l| variance(&per_layer_v[l])).sum();
    note("fig2_error_dist", &format!(
        "\nK-error variance / V-error variance = {:.2}. The paper measures \
         >1 on Llama (diffuse attention); our retrieval-trained substitute \
         is in the peaked regime where K noise is either absorbed or flips \
         the match outright — see the attention-flip metric in \
         fig1_mse_stages for the regime-independent form of the asymmetry.",
        vk / vv.max(1e-30)));
    Ok(())
}
