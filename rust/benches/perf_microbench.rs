//! Hot-path microbenchmarks: per-component timings of the decode step —
//! the instrument for the §Perf optimization loop (EXPERIMENTS.md §Perf).
//!
//! Components: RTN fold (quantize+pack), cache gather (batch assembly),
//! literal construction, artifact execution (per layer variant), and the
//! end-to-end decode step.

use std::sync::Arc;

use asymkv::engine::{Engine, SamplingParams};
use asymkv::kvcache::{CacheGeometry, SeqCache};
use asymkv::model::ByteTokenizer;
use asymkv::quant::{rtn, QuantPolicy};
use asymkv::runtime::Runtime;
use asymkv::util::bench::{fmt_duration, note, time_fn, Table};
use asymkv::util::rng::SplitMix;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();
    let n = m.n_layers;
    let geo = CacheGeometry {
        n_heads: m.n_heads,
        max_ctx: m.max_ctx,
        d_head: m.d_head,
        group: m.group,
        residual: m.residual,
    };

    note("perf_microbench", &format!(
        "\nDecode hot-path microbench — model {}, T={}, H={}, Dh={}",
        m.name, m.max_ctx, m.n_heads, m.d_head));
    let mut t = Table::new(
        "component timings",
        &["component", "p50", "min", "per-token note"],
    );

    // 1. RTN fold of one K group (quantize + pack, per head)
    let mut rng = SplitMix::new(1);
    let kg: Vec<f32> = rng.normal_f32_vec(m.group * m.d_head);
    let mut packed = vec![0u8; rtn::packed_len(m.group, 2) * m.d_head];
    let mut params =
        vec![rtn::GroupParams { scale: 0.0, zero: 0.0 }; m.d_head];
    let tm = time_fn(10, 200, || {
        rtn::fold_k_group(&kg, m.group, m.d_head, 2, &mut packed, &mut params);
    });
    t.row(vec!["rtn fold_k_group (1 head, G=32, 2b)".into(),
               fmt_duration(tm.p50()), fmt_duration(tm.min()),
               "amortized over G tokens".into()]);

    // 2. cache gather: batch assembly for one layer at B=4
    let policy = QuantPolicy::kivi(n, 2);
    let mut seqs: Vec<SeqCache> =
        (0..4).map(|_| SeqCache::new(geo, &policy)).collect();
    let hd = m.n_heads * m.d_head;
    for s in &mut seqs {
        let k: Vec<f32> = rng.normal_f32_vec(hd);
        for layer in &mut s.layers {
            for _ in 0..(m.max_ctx / 2) {
                layer.append_token(&k, &k);
            }
        }
    }
    let ggeo = asymkv::engine::gather::GatherGeo {
        b_art: 4,
        n_heads: m.n_heads,
        max_ctx: m.max_ctx,
        d_head: m.d_head,
        group: m.group,
        residual: m.residual,
    };
    let tm = time_fn(5, 100, || {
        let refs: Vec<&SeqCache> = seqs.iter().collect();
        let args = asymkv::engine::gather::gather_layer_args(&ggeo, &refs, 0);
        std::hint::black_box(&args);
    });
    t.row(vec!["gather_layer_args (B=4, 2-bit)".into(),
               fmt_duration(tm.p50()), fmt_duration(tm.min()),
               "×L per decode step".into()]);

    // 3. artifact execution per layer variant (B=4, C=1)
    let tokc = ByteTokenizer;
    for (kb, vb) in [(0u8, 0u8), (2, 2), (2, 1), (1, 1)] {
        let policy = match (kb, vb) {
            (0, 0) => QuantPolicy::float32(n),
            (a, b) => QuantPolicy::asymkv(n, n, n, a, b),
        };
        let mut p2 = policy.clone();
        p2.k_bits = vec![kb; n];
        p2.v_bits = vec![vb; n];
        let ids: Vec<u64> = (0..4)
            .map(|_| engine.create_seq(&p2).unwrap())
            .collect();
        let prompts: Vec<Vec<i32>> = (0..4)
            .map(|i| {
                let mut r = SplitMix::new(50 + i);
                tokc.encode(&asymkv::workload::gen_document(&mut r, 100))
            })
            .collect();
        engine.prefill(&ids, &prompts)?;
        let toks = [65i32, 66, 67, 68];
        let tm = time_fn(3, 30, || {
            engine.decode(&ids, &toks).unwrap();
        });
        t.row(vec![
            format!("decode step (B=4, k{kb}_v{vb}, all layers + head)"),
            fmt_duration(tm.p50()),
            fmt_duration(tm.min()),
            format!("{:.1} tok/s at B=4", 4.0 / tm.p50()),
        ]);
        for id in ids {
            engine.free_seq(id)?;
        }
    }

    // 4. single-sequence decode (B=1 artifact)
    let id = engine.create_seq(&QuantPolicy::asymkv21(n, n / 2, 0))?;
    let mut r = SplitMix::new(99);
    engine.prefill(&[id],
                   &[tokc.encode(&asymkv::workload::gen_document(&mut r, 100))])?;
    let tm = time_fn(3, 30, || {
        engine.decode(&[id], &[65]).unwrap();
    });
    t.row(vec!["decode step (B=1, AsymKV-l/0)".into(),
               fmt_duration(tm.p50()), fmt_duration(tm.min()),
               format!("{:.1} tok/s", 1.0 / tm.p50())]);
    engine.free_seq(id)?;

    // 5. generation end to end
    let tm = time_fn(1, 5, || {
        let id = engine.create_seq(&QuantPolicy::asymkv21(n, n / 2, 0)).unwrap();
        let mut r = SplitMix::new(7);
        let p = tokc.encode(&asymkv::workload::gen_document(&mut r, 100));
        engine
            .generate(&[id], &[p], 8, &SamplingParams::greedy(), 0)
            .unwrap();
        engine.free_seq(id).unwrap();
    });
    t.row(vec!["generate (prefill 100 + 8 tokens, B=1)".into(),
               fmt_duration(tm.p50()), fmt_duration(tm.min()), "".into()]);

    t.emit("perf_microbench");
    Ok(())
}
