//! Ablation: quantization group size G (the paper adopts KIVI's G = 32).
//!
//! Pure-Rust study over real cache activations (extracted through the float
//! engine): per-channel K / per-token V RTN error and metadata overhead as
//! G varies — the quality/overhead trade-off that justifies G = 32.

use std::sync::Arc;

use asymkv::engine::Engine;
use asymkv::model::ByteTokenizer;
use asymkv::quant::{rtn, QuantPolicy};
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::util::rng::SplitMix;
use asymkv::util::stats::mse;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();
    let (h, dh) = (m.n_heads, m.d_head);

    // real K/V activations via a float-policy prefill
    let tok = ByteTokenizer;
    let mut rng = SplitMix::new(0xAB6);
    let doc = asymkv::workload::gen_document(&mut rng, m.max_ctx - m.chunk);
    let id = engine.create_seq(&QuantPolicy::float32(m.n_layers))?;
    engine.prefill(&[id], &[tok.encode(&doc)])?;
    let (k_full, v_full, n_tok) = engine.with_seq(id, |s| {
        let lc = &s.layers[m.n_layers / 2];
        (lc.dequant_k_full(), lc.dequant_v_full(), lc.n_tokens())
    })?;
    engine.free_seq(id)?;

    note("ablation_groupsize", &format!(
        "\nGroup-size ablation — layer {} K/V activations, {} tokens, 2-bit",
        m.n_layers / 2, n_tok));
    let mut t = Table::new(
        "RTN error + metadata overhead vs group size (2-bit)",
        &["G", "K MSE", "V MSE", "overhead bytes/token", "total bits/value"],
    );
    let tokens_fit = |g: usize| (n_tok / g) * g; // whole groups only
    for g in [8usize, 16, 32, 64] {
        let nt = tokens_fit(g);
        if nt == 0 {
            continue;
        }
        // K per-channel: groups of g tokens along the token axis
        let mut k_err = 0.0;
        for head in 0..h {
            for gi in 0..nt / g {
                let mut kg = vec![0f32; g * dh];
                for t_ in 0..g {
                    let src = head * n_tok * dh + (gi * g + t_) * dh;
                    kg[t_ * dh..(t_ + 1) * dh]
                        .copy_from_slice(&k_full[src..src + dh]);
                }
                let mut packed = vec![0u8; rtn::packed_len(g, 2) * dh];
                let mut params =
                    vec![rtn::GroupParams { scale: 0.0, zero: 0.0 }; dh];
                rtn::fold_k_group(&kg, g, dh, 2, &mut packed, &mut params);
                let mut back = vec![0f32; g * dh];
                rtn::unfold_k_group(&packed, g, dh, 2, &params, &mut back);
                k_err += mse(&kg, &back) * (g * dh) as f64;
            }
        }
        k_err /= (h * nt * dh) as f64;
        // V per-token: groups of min(g, dh) channels
        let g2 = g.min(dh);
        let mut v_err = 0.0;
        for head in 0..h {
            let mut vg = vec![0f32; nt * dh];
            for t_ in 0..nt {
                let src = head * n_tok * dh + t_ * dh;
                vg[t_ * dh..(t_ + 1) * dh].copy_from_slice(&v_full[src..src + dh]);
            }
            let dg = dh / g2;
            let mut packed = vec![0u8; nt * rtn::packed_len(dh, 2)];
            let mut params =
                vec![rtn::GroupParams { scale: 0.0, zero: 0.0 }; nt * dg];
            rtn::fold_v_group(&vg, nt, dh, g2, 2, &mut packed, &mut params);
            let mut back = vec![0f32; nt * dh];
            rtn::unfold_v_group(&packed, nt, dh, g2, 2, &params, &mut back);
            v_err += mse(&vg, &back) * (nt * dh) as f64;
        }
        v_err /= (h * nt * dh) as f64;

        let ch = h * dh;
        let overhead = (ch * 8).div_ceil(g) + (ch / g2) * 8;
        let bits_per_val =
            2.0 + overhead as f64 * 8.0 / (2 * ch) as f64;
        t.row(vec![
            g.to_string(),
            format!("{k_err:.4e}"),
            format!("{v_err:.4e}"),
            overhead.to_string(),
            format!("{bits_per_val:.2}"),
        ]);
    }
    t.emit("ablation_groupsize");
    note("ablation_groupsize",
         "\nSmaller G → lower RTN error but more scale/zero metadata; G=32 \
          (the paper's choice, from KIVI) balances the two at ≈2.5-3.5 \
          effective bits/value.");
    Ok(())
}
