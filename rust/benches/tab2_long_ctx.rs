//! Table 2 — Evaluation on LongBench (long-context) tasks.
//!
//! Paper: at long context AsymKV needs MORE high-bit key layers than at
//! normal context (l_k = 32/40 = ALL layers vs 16/20 at normal ctx), and
//! AsymKV-l/0 still dominates AsymKV-0/l.
//!
//! Here (DESIGN.md §1): the `small-long` artifacts (ctx 512, same weights),
//! needle-in-a-haystack recall (↔ LongBench retrieval tasks) + long-doc
//! perplexity (↔ summarization-style likelihood).

use std::sync::Arc;

use asymkv::engine::Engine;
use asymkv::evals;
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::workload::{self, tasks};

fn main() -> anyhow::Result<()> {
    let dir =
        std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small-long".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();
    let n = m.n_layers;
    let l = n; // long context: full key budget (paper: l_k = all layers)

    // needle episodes byte-budgeted to ~2/3 of the long context
    let target = m.max_ctx * 2 / 3;
    let suite = tasks::needle_suite_bytes(0x7AB2, 20, target);
    let docs: Vec<Vec<u8>> = (0..4)
        .map(|i| workload::eval_doc(2, i, m.max_ctx - m.chunk))
        .collect();

    note("tab2_long_ctx", &format!(
        "\nTable 2 reproduction — model {}, ctx {}, {} needle episodes \
         (~{} filler bytes), l = {l} of {n} \
         (paper: LongBench, l_k = 32/40 of 32/40)",
        m.name, m.max_ctx, suite.len(), target));

    let mut t = Table::new(
        "Tab.2: long-context quality",
        &["type", "needle acc ↑", "ppl ↓", "≥90% float?"],
    );
    let mut float_acc = 0.0;
    for policy in evals::table_policies(n, l) {
        let acc = evals::recall_accuracy(&engine, &policy, &suite)?;
        let ppl = evals::perplexity(&engine, &policy, &docs)?;
        if policy.name == "float" {
            float_acc = acc;
        }
        t.row(vec![
            policy.name.clone(),
            format!("{acc:.3}"),
            format!("{ppl:.2}"),
            if evals::meets_90pct(acc, float_acc) { "*" } else { "" }.into(),
        ]);
    }
    t.emit("tab2_long_ctx");
    note("tab2_long_ctx",
         "\nPaper shape: keys-high beats values-high at long range too, and \
          long context needs a larger l_k than Table 1 to stay within 90 %.");
    Ok(())
}
