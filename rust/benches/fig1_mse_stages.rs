//! Fig. 1 — Squared error in the inference of attention.
//!
//! Paper: the MSE of the attention output under K-quantization vs
//! V-quantization, measured after each stage (Equ. 6 dequant → Equ. 1
//! scores → Equ. 2 softmax → Equ. 3 output), on real Llama-2-7b
//! activations; the K/V ratio grows across the stages.
//!
//! Here: real activations of the pretrained `small` model (DESIGN.md §1),
//! captured via the probe artifact and measured in-graph by the
//! stage_mse artifact. Expected shape: ratio ≈ 1 at the dequant stage,
//! amplified (≫1) after the query matmul and the softmax.

use std::sync::Arc;

use asymkv::analysis;
use asymkv::engine::Engine;
use asymkv::model::ByteTokenizer;
use asymkv::runtime::Runtime;
use asymkv::util::bench::{note, Table};
use asymkv::util::rng::SplitMix;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ASYMKV_ARTIFACTS").unwrap_or("artifacts/small".into());
    // CI's bench-smoke job runs without AOT artifacts: prove the target
    // executes end-to-end where possible, skip cleanly where not
    let rt = match Runtime::load(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) if asymkv::util::bench::smoke() => {
            println!("[bench-smoke] artifacts unavailable ({e}); skipping");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let engine = Engine::new(rt, 1 << 30)?;
    let m = engine.manifest();

    // probe at RETRIEVAL positions: recall episodes make the query token
    // the probe position, where attention is peaked and the softmax
    // amplification of key error manifests (diffuse positions show none —
    // the same position-dependence underlies the paper's task results)
    let tok = ByteTokenizer;
    let mut all_acts = Vec::new();
    for seed in 0..4u64 {
        let mut rng = SplitMix::new(0xF161 + seed);
        let ep = asymkv::workload::tasks::recall_episode(&mut rng, 18);
        all_acts.push(analysis::collect_activations(&engine,
                                                    &tok.encode(&ep.prompt))?);
    }
    let acts: Vec<_> = all_acts.into_iter().flatten().collect();
    let bits: u8 = std::env::var("ASYMKV_FIG1_BITS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);

    note("fig1_mse_stages",
         &format!("\nFig. 1 reproduction — model {}, {} probed (layer, \
                   retrieval-position) samples, {bits}-bit quantization \
                   (paper: Llama-2-7b, 2-bit)",
                  m.name, acts.len()));
    let mut t = Table::new(
        "Fig.1: attention-output MSE by stage (K-quant vs V-quant)",
        &["layer", "stage", "MSE (K quant)", "MSE (V quant)", "K/V ratio"],
    );
    let stages = ["Equ.6 dequant", "Equ.1 scores", "Equ.2 softmax", "Equ.3 output"];
    let mut agg = [[0.0f64; 4]; 2];
    for a in &acts {
        let s = analysis::stage_mse(&engine, a, bits)?;
        for st in 0..4 {
            agg[0][st] += s.mse_k[st];
            agg[1][st] += s.mse_v[st];
        }
        for (st, name) in stages.iter().enumerate() {
            let ratio = if s.mse_v[st] > 0.0 {
                format!("{:.2}", s.mse_k[st] / s.mse_v[st])
            } else {
                "-".into()
            };
            t.row(vec![
                a.layer.to_string(),
                name.to_string(),
                format!("{:.3e}", s.mse_k[st]),
                format!("{:.3e}", s.mse_v[st]),
                ratio,
            ]);
        }
    }
    t.emit("fig1_mse_stages");

    let n = (acts.len() / m.n_layers).max(1) as f64 * m.n_layers as f64;
    let mut t2 = Table::new(
        "Fig.1 (aggregate over layers): the amplification curve",
        &["stage", "mean MSE (K)", "mean MSE (V)", "K/V ratio"],
    );
    for (st, name) in stages.iter().enumerate() {
        let (k, v) = (agg[0][st] / n, agg[1][st] / n);
        t2.row(vec![
            name.to_string(),
            format!("{k:.3e}"),
            format!("{v:.3e}"),
            if v > 0.0 { format!("{:.2}", k / v) } else { "-".into() },
        ]);
    }
    t2.emit("fig1_mse_stages");

    let r0 = agg[0][0] / agg[1][0].max(1e-30);
    let r3 = agg[0][3] / agg[1][3].max(1e-30);
    note("fig1_mse_stages", &format!(
        "\nMSE-ratio check: dequant-stage ratio {r0:.2}, output-stage ratio \
         {r3:.2}. The paper's Llama measurement shows ≫1 (diffuse natural-\
         text attention: score noise reshuffles weights while V noise \
         averages out). Our retrieval-trained substitute sits in the \
         opposite regime — attention is sharply peaked, so V noise passes \
         through ~linearly while K noise either leaves the match intact \
         (≈0 error) or FLIPS it (fatal but rare in MSE terms)."));

    // the mechanism metric that is regime-independent: how often does
    // quantization corrupt attention ADDRESSING?
    let (flip_k, margin) = asymkv::analysis::attention_flip_rate(
        &acts, m.n_heads, m.d_head, m.group, bits);
    note("fig1_mse_stages", &format!(
        "\nAttention-flip check (argmax of attention moves under \
         quantization): K-quant flips {:.1}% of probed heads at {bits}-bit \
         (mean top-1 score margin {margin:.2}); V-quant flips 0% \
         structurally (V enters after the softmax). Key quantization is \
         the only one that corrupts addressing — the paper's §3 asymmetry. \
         {}",
        flip_k * 100.0,
        if flip_k > 0.0 { "REPRODUCED (flip-rate form)" } else { "no flips at this bit-width" }));
    Ok(())
}
