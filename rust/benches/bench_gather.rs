//! Batch-assembly bench: `gather_layer_args` (the per-layer scatter of
//! packed caches + residual rings + masks into artifact-shaped buffers)
//! and full-cache dequantization through the dispatched kernels.
//! Pure-Rust (no artifacts), runs everywhere. Emits the `gather_*` and
//! `dequant_*` records of `BENCH_kernels.json`.

use asymkv::engine::gather::{gather_layer_args, GatherGeo};
use asymkv::kvcache::{CacheGeometry, SeqCache};
use asymkv::quant::QuantPolicy;
use asymkv::util::bench::{self, fmt_duration, fmt_throughput, time_fn, JsonReport, Table};
use asymkv::util::json::Value;
use asymkv::util::rng::SplitMix;

const B: usize = 4;
const LAYERS: usize = 2;

fn main() {
    let geo = CacheGeometry { n_heads: 8, max_ctx: 256, d_head: 64, group: 32, residual: 64 };
    let ggeo = GatherGeo {
        b_art: B,
        n_heads: geo.n_heads,
        max_ctx: geo.max_ctx,
        d_head: geo.d_head,
        group: geo.group,
        residual: geo.residual,
    };
    let reps = bench::samples(100);
    let warm = bench::warmup(10);
    let mut rng = SplitMix::new(0x9A7E);
    let hd = geo.n_heads * geo.d_head;

    bench::note(
        "bench_gather",
        &format!(
            "\nBatch assembly — B={B}, H={}, T={}, Dh={}, half-full caches, {reps} samples",
            geo.n_heads, geo.max_ctx, geo.d_head
        ),
    );
    let mut t = Table::new(
        "gather_layer_args / dequant_full",
        &["op", "policy", "p50", "throughput"],
    );
    let mut report = JsonReport::at_root("BENCH_kernels.json");

    for (pname, policy) in [
        ("1bit", QuantPolicy::kivi(LAYERS, 1)),
        ("2bit", QuantPolicy::kivi(LAYERS, 2)),
        ("float", QuantPolicy::float32(LAYERS)),
    ] {
        let mut seqs: Vec<SeqCache> =
            (0..B).map(|_| SeqCache::new(geo, &policy)).collect();
        let fill = geo.max_ctx / 2;
        for s in &mut seqs {
            for layer in &mut s.layers {
                let ks: Vec<f32> = rng.normal_f32_vec(fill * hd);
                let vs: Vec<f32> = rng.normal_f32_vec(fill * hd);
                layer.append_tokens(fill, &ks, &vs);
            }
        }
        // bytes actually moved per gather: every slot's cache + params +
        // residual buffers
        let bytes: usize = seqs.iter().map(|s| s.layers[0].used_bytes()).sum();

        let tm = time_fn(warm, reps, || {
            let refs: Vec<&SeqCache> = seqs.iter().collect();
            let args = gather_layer_args(&ggeo, &refs, 0);
            std::hint::black_box(&args);
        });
        t.row(vec![
            "gather".into(),
            pname.into(),
            fmt_duration(tm.p50()),
            fmt_throughput(bytes as f64 / tm.mean()),
        ]);
        report.add(
            &format!("gather_b{B}_{pname}"),
            &tm,
            bytes,
            gather_cfg(&geo, pname),
        );

        // full dequant of one layer cache through the dispatched kernels
        let dq_bytes = geo.n_heads * seqs[0].layers[0].n_tokens() * geo.d_head * 4;
        let tm = time_fn(warm, reps, || {
            let full = seqs[0].layers[0].dequant_k_full();
            std::hint::black_box(&full);
        });
        t.row(vec![
            "dequant_k_full".into(),
            pname.into(),
            fmt_duration(tm.p50()),
            fmt_throughput(dq_bytes as f64 / tm.mean()),
        ]);
        report.add(
            &format!("dequant_k_full_{pname}"),
            &tm,
            dq_bytes,
            gather_cfg(&geo, pname),
        );
    }

    t.emit("bench_gather");
    report.write().expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json (gather_*/dequant_* records)");
}

fn gather_cfg(geo: &CacheGeometry, pname: &str) -> Value {
    Value::obj(vec![
        ("b", Value::num(B as f64)),
        ("heads", Value::num(geo.n_heads as f64)),
        ("max_ctx", Value::num(geo.max_ctx as f64)),
        ("dh", Value::num(geo.d_head as f64)),
        ("policy", Value::str_of(pname)),
    ])
}
