//! Paged cache-pool concurrency bench: the Fig. 4 serving argument made
//! measurable. Under the old capacity-reservation pool every sequence was
//! charged its full-context footprint at admission, so a fixed byte budget
//! admitted `budget / full_capacity` sequences no matter how short they
//! were. The demand-paged pool charges only resident pages, so the same
//! budget holds several times more concurrently active short sequences —
//! and an over-subscribed decode stress run completes with preemption
//! requeues instead of panics. Pure-Rust (no artifacts), runs everywhere.
//! Emits the `pool_*` records of `BENCH_kernels.json`.

use asymkv::kvcache::{CacheGeometry, CachePool};
use asymkv::quant::QuantPolicy;
use asymkv::util::bench::{self, fmt_duration, time_fn, JsonReport, Table};
use asymkv::util::json::Value;

// bench-scale geometry: bench_fold's 8×128 heads, but a longer context —
// the reservation baseline's cost scales with T while a short sequence's
// resident pages do not, which is exactly the asymmetry being measured
const GEO: CacheGeometry = CacheGeometry {
    n_heads: 8,
    max_ctx: 1024,
    d_head: 128,
    group: 32,
    residual: 64,
};
const LAYERS: usize = 4;
/// a "short sequence": 16-token prompt + 16 generated tokens
const SHORT_TOKENS: usize = 32;

fn policy() -> QuantPolicy {
    QuantPolicy::kivi(LAYERS, 2)
}

/// Append `count` identical tokens to every layer of `id` (the accounting
/// only depends on counts, not values).
fn grow(pool: &CachePool, id: u64, count: usize) {
    let hd = GEO.n_heads * GEO.d_head;
    let row = vec![0.5f32; hd];
    pool.with_seq(id, |s| {
        for layer in &mut s.layers {
            for _ in 0..count {
                layer.append_token(&row, &row);
            }
        }
        s.pos += count;
    })
    .unwrap();
}

/// How many short sequences fit concurrently under `budget` with paged
/// admission + growth (each is admitted with the projected-pages gate,
/// then actually grown to SHORT_TOKENS so its pages are resident).
fn paged_short_concurrency(pool: &CachePool) -> (usize, Vec<u64>) {
    let p = policy();
    let mut ids = Vec::new();
    while pool.admit(&p, SHORT_TOKENS).is_ok() {
        let id = pool.allocate(&p).unwrap();
        grow(pool, id, SHORT_TOKENS);
        ids.push(id);
    }
    (ids.len(), ids)
}

/// Over-subscribed decode stress: `m` requests of `total` tokens each are
/// driven through a scheduler-shaped loop against a budget sized for ~2
/// fully grown sequences. Admission is optimistic (projected pages), so
/// mid-decode page reservations collide; every collision must preempt the
/// youngest active request back to the queue (restart from scratch) —
/// never panic, never fail. Returns (preemptions, peak_active).
fn preempt_stress(pool: &CachePool, m: usize, total: usize) -> (u64, usize) {
    let p = policy();
    let mut pending: std::collections::VecDeque<usize> = (0..m).collect();
    // (request, seq id, tokens resident)
    let mut active: Vec<(usize, u64, usize)> = Vec::new();
    let mut preemptions = 0u64;
    let mut peak_active = 0usize;
    let mut completed = 0usize;
    while completed < m {
        // admit while the projected footprint fits (optimistic)
        while active.len() < m
            && !pending.is_empty()
            && pool.admit(&p, total).is_ok()
        {
            let req = pending.pop_front().unwrap();
            let id = pool.allocate(&p).unwrap();
            active.push((req, id, 0));
        }
        peak_active = peak_active.max(active.len());
        assert!(
            !active.is_empty(),
            "stress must always make progress (budget fits at least one)"
        );
        // one decode step per active request; a page collision preempts
        // the youngest (last-admitted) request instead of panicking
        let mut i = 0;
        while i < active.len() {
            let (_, id, _) = active[i];
            if pool.reserve_growth(&[id], &[1]).is_err() {
                let (req, vid, _) = active.pop().unwrap(); // youngest
                pool.free(vid).unwrap();
                pending.push_back(req); // requeue, NOT an error
                preemptions += 1;
                break; // re-admit next round (indices shifted)
            }
            grow(pool, id, 1);
            active[i].2 += 1;
            if active[i].2 == total {
                // order-preserving removal keeps `active` in admission
                // order, so `pop()` above always evicts the youngest
                let (_, fid, _) = active.remove(i);
                pool.free(fid).unwrap();
                completed += 1;
            } else {
                i += 1;
            }
        }
    }
    (preemptions, peak_active)
}

fn main() {
    let p = policy();
    let probe = CachePool::new(GEO, usize::MAX);
    let full = {
        // a fully grown sequence's resident footprint (== the old static
        // capacity reservation): grow one to the context limit
        let id = probe.allocate(&p).unwrap();
        grow(&probe, id, GEO.max_ctx + GEO.residual - 1);
        let b = probe.with_seq(id, |s| s.capacity_bytes()).unwrap();
        probe.free(id).unwrap();
        b
    };
    let short = probe.estimate_bytes(&p, SHORT_TOKENS);

    // ---- concurrency under a fixed budget: paged vs reservation ----
    const RESERVED_ACTIVE: usize = 8; // baseline: budget admits exactly 8
    let budget = RESERVED_ACTIVE * full;
    let pool = CachePool::new(GEO, budget);
    let (paged_active, ids) = paged_short_concurrency(&pool);
    let ratio = paged_active as f64 / RESERVED_ACTIVE as f64;
    for id in ids {
        pool.free(id).unwrap();
    }
    assert!(
        ratio >= 4.0,
        "paged pool must hold >= 4x more short sequences than the \
         capacity-reservation baseline (got {paged_active} vs {RESERVED_ACTIVE})"
    );

    let mut t = Table::new(
        "paged pool: concurrently active short sequences (same byte budget)",
        &["accounting", "bytes/seq", "active", "vs reservation"],
    );
    t.row(vec![
        "capacity reservation".into(),
        full.to_string(),
        RESERVED_ACTIVE.to_string(),
        "1.0x".into(),
    ]);
    t.row(vec![
        "demand-paged".into(),
        short.to_string(),
        paged_active.to_string(),
        format!("{ratio:.1}x"),
    ]);

    let mut report = JsonReport::at_root("BENCH_kernels.json");
    let reps = bench::samples(20);
    let warm = bench::warmup(2);

    // timed: the full admit -> grow -> free cycle for the paged fleet
    let tm = time_fn(warm, reps, || {
        let pool = CachePool::new(GEO, budget);
        let (_, ids) = paged_short_concurrency(&pool);
        for id in ids {
            pool.free(id).unwrap();
        }
        std::hint::black_box(pool.stats().peak_bytes);
    });
    t.row(vec![
        "admit+grow+free cycle".into(),
        short.to_string(),
        paged_active.to_string(),
        fmt_duration(tm.p50()),
    ]);
    report.add(
        "pool_paged_concurrency",
        &tm,
        budget,
        Value::obj(vec![
            ("budget_bytes", Value::num(budget as f64)),
            ("full_seq_bytes", Value::num(full as f64)),
            ("short_seq_bytes", Value::num(short as f64)),
            ("tokens_per_seq", Value::num(SHORT_TOKENS as f64)),
            ("reserved_active", Value::num(RESERVED_ACTIVE as f64)),
            ("paged_active", Value::num(paged_active as f64)),
            ("ratio_vs_reservation", Value::num(ratio)),
            ("layers", Value::num(LAYERS as f64)),
            ("policy", Value::str_of(p.name.clone())),
        ]),
    );

    // ---- over-subscribed stress: preemption requeues, zero panics ----
    let stress_total = 320usize; // tokens per request (folds well past R)
    let stress_m = 8usize;
    let stress_budget = {
        let probe = CachePool::new(GEO, usize::MAX);
        let two = 2 * probe.estimate_bytes(&p, stress_total);
        two + two / 10 // ~2.2 fully grown stress sequences
    };
    let pool = CachePool::new(GEO, stress_budget);
    let (preemptions, peak_active) = preempt_stress(&pool, stress_m, stress_total);
    assert_eq!(pool.stats().n_seqs, 0, "stress must release every sequence");
    assert!(
        preemptions > 0,
        "the stress budget must actually over-subscribe (got no preemptions)"
    );
    let tm = time_fn(bench::warmup(1), bench::samples(5), || {
        let pool = CachePool::new(GEO, stress_budget);
        std::hint::black_box(preempt_stress(&pool, stress_m, stress_total));
    });
    t.row(vec![
        "preempt stress (8 reqs)".into(),
        stress_budget.to_string(),
        format!("peak {peak_active}"),
        fmt_duration(tm.p50()),
    ]);
    let stress_bytes = stress_m * stress_total * GEO.n_heads * GEO.d_head * 4 * 2 * LAYERS;
    report.add(
        "pool_preempt_stress",
        &tm,
        stress_bytes,
        Value::obj(vec![
            ("budget_bytes", Value::num(stress_budget as f64)),
            ("requests", Value::num(stress_m as f64)),
            ("tokens_per_request", Value::num(stress_total as f64)),
            ("preemptions", Value::num(preemptions as f64)),
            ("peak_active", Value::num(peak_active as f64)),
            ("completed", Value::num(stress_m as f64)),
            ("panics", Value::num(0.0)),
            ("policy", Value::str_of(p.name.clone())),
        ]),
    );

    t.emit("bench_pool");
    bench::note(
        "bench_pool",
        &format!(
            "\nSame {budget}-byte budget: {RESERVED_ACTIVE} sequences under \
             capacity reservation vs {paged_active} demand-paged ({ratio:.1}x); \
             over-subscribed stress completed 8/8 with {preemptions} preemption \
             requeues and zero panics."
        ),
    );
    report.write().expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json (pool_* records)");
}
